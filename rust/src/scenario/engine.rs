//! The dynamic-world simulation driver.
//!
//! [`simulate_scenario_with`] is the streaming k-way merge of
//! [`crate::sim::engine::simulate_with`] extended with a fourth input
//! stream: the scenario's [`WorldEvent`] timeline. Trace events apply
//! in the same `(time, kind, page)` total order as the static engine;
//! world events at time `t` apply before any trace event at `t`
//! (script order among themselves). With an empty timeline every
//! operation — heap arithmetic, freshness accounting, timeline ring —
//! degenerates to the static engine's, so an empty scenario is
//! **bit-identical** to `simulate_with` (pinned by
//! `tests/scenario_parity.rs`).
//!
//! ## Slots, recycling, and stream versions
//!
//! The workspace owns a mutable copy of the per-page event streams.
//! Page slots carry two counters:
//!
//! - a **generation** counter (incremented on every retire and every
//!   rebirth) — the audit trail proving a recycled slot never aliases
//!   its previous occupant's state;
//! - a **stream version**, stamped into every merge-heap entry. Any
//!   mutation that invalidates a page's pending heap entry (retirement,
//!   future-stream regeneration) bumps the version; stale entries are
//!   discarded on pop without advancing cursors, so the one-valid-entry
//!   -per-live-page merge invariant survives arbitrary churn.
//!
//! Retirement truncates the unapplied stream tails and frees the slot
//! (LIFO); a birth recycles the most recently freed slot or grows the
//! population. Regeneration (parameter drift / CIS-quality shifts)
//! replaces only the *future*: applied history is never rewritten.
//!
//! `SimResult::crawl_counts` under a dynamic world counts crawls of
//! each slot's **current occupant** (a birth zeroes the slot's count),
//! so `empirical_rates` stays meaningful per page, not per slot.

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::rngkit::Rng;
use crate::scenario::{PageSet, Scenario, TimedEvent, WorldEvent};
use crate::sched::CrawlScheduler;
use crate::serving::ServingSession;
use crate::sim::engine::{BandwidthSchedule, SimConfig, SimResult};
use crate::sim::engine::{KIND_CHANGE, KIND_CIS, KIND_REQUEST};
use crate::sim::events::{generate_page_trace_from, CisDelay, EventTraces, PageTrace};
use crate::sim::source::PageEventSource;
use crate::trace::{self, world_kind, SpanKind, TraceEvent};
use crate::util::OrdF64;

/// Heap entry: `(time, kind, page, stream version)`. The version is a
/// pure validity stamp — it only breaks ties between a stale and a
/// fresh entry of the *same* page, where yield order is immaterial
/// (the stale one is discarded either way) — so the effective total
/// order is the static engine's `(time, kind, page)`.
type MergeEntry = Reverse<(OrdF64, u8, u32, u32)>;

/// Counters of what the world did to one run.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct ScenarioStats {
    /// Pages born (fresh slots + recycled).
    pub births: u64,
    /// Pages retired.
    pub retirements: u64,
    /// Parameter shifts applied.
    pub param_shifts: u64,
    /// CIS-quality shifts applied.
    pub quality_shifts: u64,
    /// Outage windows opened.
    pub outages: u64,
    /// CIS deliveries suppressed by an outage window. An in-outage CIS
    /// counts here regardless of the Appendix-C discard window (outage
    /// suppression is checked first in both trace modes), so the
    /// materialized and streamed engines account suppression
    /// identically — the fuzzer's invariant audit depends on this
    /// (pinned by `suppression_counting_is_mode_identical` below).
    pub cis_suppressed: u64,
    /// Events that named a dead/out-of-range page (no-ops).
    pub skipped_events: u64,
    /// Scheduler picks of a retired slot (the tick is forfeited).
    /// Stays 0 for hook-aware schedulers (the parity suite asserts
    /// it); counts wasted crawls for hook-less baselines whose plan
    /// predates the churn (e.g. LDS) — a static schedule fetching a
    /// dead URL.
    pub stale_picks: u64,
}

/// Reusable scratch + world state of the scenario engine. Mirrors
/// [`crate::sim::SimWorkspace`] and adds the slot registry (liveness,
/// generations, free list), per-page stream versions and the outage
/// windows. `reset` clears without releasing capacity.
#[derive(Debug, Default)]
pub struct ScenarioWorkspace {
    /// Mutable copy of the per-page event streams (grows on births;
    /// materialized mode only).
    pages: Vec<PageTrace>,
    /// Per-page lazy sources (streamed mode only; births replace, a
    /// retirement kills the slot's source in place).
    lazy: Vec<PageEventSource>,
    live: Vec<bool>,
    generation: Vec<u32>,
    stream_ver: Vec<u32>,
    /// Retired slots available for recycling (LIFO).
    free: Vec<usize>,
    /// CIS deliveries before this time are suppressed (outages).
    cis_off_until: Vec<f64>,
    /// High-water of `PageSet::All` outage windows: pages born while a
    /// global blackout is active inherit it (a dark feed is dark for
    /// newcomers too); host-targeted outages list explicit slots and
    /// cannot name pages that do not exist yet.
    global_off_until: f64,
    last_crawl: Vec<f64>,
    changed: Vec<bool>,
    crawl_counts: Vec<u32>,
    ring: Vec<bool>,
    heap: BinaryHeap<MergeEntry>,
    cursors: Vec<[usize; 3]>,
    /// What the world did during the last run.
    pub stats: ScenarioStats,
}

impl ScenarioWorkspace {
    /// Empty workspace; buffers grow on first use and are reused after.
    pub fn new() -> Self {
        Self::default()
    }

    /// Common slot-state reset for `m` initial pages (both modes).
    fn reset_slots(&mut self, m: usize) {
        self.live.clear();
        self.live.resize(m, true);
        self.generation.clear();
        self.generation.resize(m, 0);
        self.stream_ver.clear();
        self.stream_ver.resize(m, 0);
        self.free.clear();
        self.cis_off_until.clear();
        self.cis_off_until.resize(m, f64::NEG_INFINITY);
        self.global_off_until = f64::NEG_INFINITY;
        self.last_crawl.clear();
        self.last_crawl.resize(m, 0.0);
        self.changed.clear();
        self.changed.resize(m, false);
        self.crawl_counts.clear();
        self.crawl_counts.resize(m, 0);
        self.ring.clear();
        self.heap.clear();
        self.cursors.clear();
        self.cursors.resize(m, [0, 0, 0]);
        self.stats = ScenarioStats::default();
    }

    /// Reset for a materialized run over `traces`.
    fn reset(&mut self, traces: &[PageTrace]) {
        self.pages.clear();
        self.pages.extend(traces.iter().cloned());
        self.lazy.clear();
        self.reset_slots(traces.len());
    }

    /// Reset for a streamed run: per-page lazy sources over the
    /// scenario's initial population, keyed exactly like
    /// `generate_traces` (`master.split(i)`).
    fn reset_streamed(&mut self, scenario: &Scenario, horizon: f64, trace_seed: u64) {
        self.pages.clear();
        self.lazy.clear();
        let initial = scenario.initial_pages();
        let mut master = Rng::new(trace_seed);
        for (i, p) in initial.iter().enumerate() {
            let mut prng = master.split(i as u64);
            self.lazy.push(PageEventSource::new(p, 0.0, horizon, scenario.delay(), &mut prng));
        }
        self.reset_slots(initial.len());
    }

    /// Common slot-column growth (both modes); the caller appends to
    /// `pages`/`lazy` itself. Returns the new slot index.
    fn grow_slot_columns(&mut self) -> usize {
        self.live.push(false);
        self.generation.push(0);
        self.stream_ver.push(0);
        self.cis_off_until.push(f64::NEG_INFINITY);
        self.last_crawl.push(0.0);
        self.changed.push(false);
        self.crawl_counts.push(0);
        self.cursors.push([0, 0, 0]);
        self.live.len() - 1
    }

    /// Append one empty slot (materialized mode); returns its index.
    fn grow_one(&mut self) -> usize {
        self.pages.push(PageTrace::default());
        self.grow_slot_columns()
    }

    /// Current slot count (live + retired).
    pub fn population(&self) -> usize {
        self.live.len()
    }

    /// Is slot `page` currently live?
    pub fn is_live(&self, page: usize) -> bool {
        self.live[page]
    }

    /// Lifecycle generation of slot `page` (audit hook: +1 per
    /// retirement and per rebirth).
    pub fn generation(&self, page: usize) -> u32 {
        self.generation[page]
    }
}

/// Deterministic per-world-event RNG: replaying the same scenario
/// (same seed, same event index) regenerates identical streams.
fn event_rng(seed: u64, idx: usize) -> Rng {
    Rng::new(seed ^ (idx as u64 + 1).wrapping_mul(0x9E37_79B9_7F4A_7C15))
}

/// Push page `page`'s next pending event onto the merge heap, stamped
/// with its current stream version (the 4-field analogue of the static
/// engine's `push_next`).
#[inline]
fn push_next(
    heap: &mut BinaryHeap<MergeEntry>,
    p: &PageTrace,
    cursors: &[usize; 3],
    page: u32,
    ver: u32,
) {
    let mut best: Option<(f64, u8)> = None;
    if let Some(&t) = p.changes.get(cursors[0]) {
        best = Some((t, KIND_CHANGE));
    }
    if let Some(&t) = p.cis.get(cursors[1]) {
        if best.map_or(true, |(bt, bk)| t < bt || (t == bt && KIND_CIS < bk)) {
            best = Some((t, KIND_CIS));
        }
    }
    if let Some(&t) = p.requests.get(cursors[2]) {
        if best.map_or(true, |(bt, bk)| t < bt || (t == bt && KIND_REQUEST < bk)) {
            best = Some((t, KIND_REQUEST));
        }
    }
    if let Some((t, k)) = best {
        heap.push(Reverse((OrdF64(t), k, page, ver)));
    }
}

/// Splice the scenario's `BandwidthChange` directives into the base
/// schedule: both streams are directives sorted by time, the latest
/// one wins at any instant (a scenario directive overrides a base
/// segment starting at the same time). No changes → the base schedule,
/// verbatim.
fn effective_bandwidth(base: &BandwidthSchedule, events: &[TimedEvent]) -> BandwidthSchedule {
    let changes: Vec<(f64, f64)> = events
        .iter()
        .filter_map(|e| match e.event {
            WorldEvent::BandwidthChange { rate } => Some((e.t, rate)),
            _ => None,
        })
        .collect();
    if changes.is_empty() {
        return base.clone();
    }
    // (time, source rank, source order, rate): base before scenario at
    // equal times so the scenario directive overwrites it below
    let mut dirs: Vec<(f64, u8, usize, f64)> = Vec::new();
    for (k, &(t, r)) in base.segments().iter().enumerate() {
        dirs.push((t, 0, k, r));
    }
    for (k, &(t, r)) in changes.iter().enumerate() {
        dirs.push((t, 1, k, r));
    }
    dirs.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)).then(a.2.cmp(&b.2)));
    let mut segs: Vec<(f64, f64)> = Vec::new();
    for (t, _, _, r) in dirs {
        match segs.last_mut() {
            Some(last) if last.0 == t => last.1 = r, // later directive wins
            _ => segs.push((t, r)),
        }
    }
    BandwidthSchedule::new(segs)
        .unwrap_or_else(|e| unreachable!("validated directives merge into a valid schedule: {e}"))
}

/// Apply one world event at its time `ev.t`. `idx` is the event's
/// timeline index (drives the deterministic regeneration RNG).
fn apply_world(
    ws: &mut ScenarioWorkspace,
    scheduler: &mut dyn CrawlScheduler,
    ev: &TimedEvent,
    idx: usize,
    scenario: &Scenario,
    horizon: f64,
    serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) {
    let tw = ev.t;
    match &ev.event {
        WorldEvent::PageBorn { params } => {
            let slot = match ws.free.pop() {
                Some(s) => {
                    ws.generation[s] = ws.generation[s].wrapping_add(1);
                    s
                }
                None => ws.grow_one(),
            };
            ws.live[slot] = true;
            ws.stream_ver[slot] = ws.stream_ver[slot].wrapping_add(1);
            ws.cursors[slot] = [0, 0, 0];
            ws.changed[slot] = false;
            ws.last_crawl[slot] = tw;
            // crawl_counts describe the slot's CURRENT occupant: the
            // previous occupant's crawls must not pollute the
            // newcomer's empirical rate
            ws.crawl_counts[slot] = 0;
            // a global blackout covers newcomers; host-level outages
            // (explicit slot lists) cannot name the unborn
            ws.cis_off_until[slot] = ws.global_off_until;
            let mut rng = event_rng(scenario.seed(), idx);
            ws.pages[slot] =
                generate_page_trace_from(params, tw, horizon, scenario.delay(), &mut rng);
            ws.stats.births += 1;
            trace::emit(tr, || TraceEvent::World { t: tw, kind: world_kind::BORN, page: slot as u32 });
            scheduler.on_page_added(slot, params, tw);
            if let Some(sv) = serving {
                sv.on_page_added(slot, params);
            }
            push_next(
                &mut ws.heap,
                &ws.pages[slot],
                &ws.cursors[slot],
                slot as u32,
                ws.stream_ver[slot],
            );
        }
        WorldEvent::PageRetired { page } => {
            let i = *page;
            if i >= ws.live.len() || !ws.live[i] {
                ws.stats.skipped_events += 1;
                return;
            }
            ws.live[i] = false;
            ws.generation[i] = ws.generation[i].wrapping_add(1);
            // the pending heap entry dies with the version; the
            // unapplied tails can never replay, so drop them
            ws.stream_ver[i] = ws.stream_ver[i].wrapping_add(1);
            let c = ws.cursors[i];
            ws.pages[i].changes.truncate(c[0]);
            ws.pages[i].cis.truncate(c[1]);
            ws.pages[i].requests.truncate(c[2]);
            ws.free.push(i);
            ws.stats.retirements += 1;
            trace::emit(tr, || TraceEvent::World { t: tw, kind: world_kind::RETIRED, page: i as u32 });
            scheduler.on_page_removed(i, tw);
        }
        WorldEvent::ParamsChanged { page, params } => {
            let i = *page;
            if i >= ws.live.len() || !ws.live[i] {
                ws.stats.skipped_events += 1;
                return;
            }
            let c = ws.cursors[i];
            ws.pages[i].changes.truncate(c[0]);
            ws.pages[i].cis.truncate(c[1]);
            ws.pages[i].requests.truncate(c[2]);
            let mut rng = event_rng(scenario.seed(), idx);
            let fresh = generate_page_trace_from(params, tw, horizon, scenario.delay(), &mut rng);
            ws.pages[i].changes.extend(fresh.changes);
            ws.pages[i].cis.extend(fresh.cis);
            ws.pages[i].requests.extend(fresh.requests);
            ws.stream_ver[i] = ws.stream_ver[i].wrapping_add(1);
            ws.stats.param_shifts += 1;
            trace::emit(tr, || TraceEvent::World { t: tw, kind: world_kind::PARAMS, page: i as u32 });
            scheduler.on_params_changed(i, params, tw);
            push_next(&mut ws.heap, &ws.pages[i], &ws.cursors[i], i as u32, ws.stream_ver[i]);
        }
        WorldEvent::CisQualityShift { page, lam, nu } => {
            let i = *page;
            if i >= ws.live.len() || !ws.live[i] {
                ws.stats.skipped_events += 1;
                return;
            }
            // re-draw future CIS against the EXISTING future change
            // realization; in-flight deliveries of the old feed drop
            let mut rng = event_rng(scenario.seed(), idx);
            let mut cis: Vec<f64> = Vec::new();
            for &ct in &ws.pages[i].changes[ws.cursors[i][0]..] {
                if rng.bernoulli(*lam) {
                    let d = ct + scenario.delay().sample(&mut rng);
                    if d < horizon {
                        cis.push(d);
                    }
                }
            }
            for t in crate::rngkit::poisson_process(&mut rng, *nu, horizon - tw) {
                let d = tw + t + scenario.delay().sample(&mut rng);
                if d < horizon {
                    cis.push(d);
                }
            }
            cis.sort_unstable_by(f64::total_cmp);
            ws.pages[i].cis.truncate(ws.cursors[i][1]);
            ws.pages[i].cis.extend(cis);
            ws.stream_ver[i] = ws.stream_ver[i].wrapping_add(1);
            ws.stats.quality_shifts += 1;
            trace::emit(tr, || TraceEvent::World { t: tw, kind: world_kind::QUALITY, page: i as u32 });
            // the scheduler is NOT notified: its beliefs go stale
            push_next(&mut ws.heap, &ws.pages[i], &ws.cursors[i], i as u32, ws.stream_ver[i]);
        }
        WorldEvent::CisOutage { pages, duration } => {
            let until = tw + duration;
            match pages {
                PageSet::All => {
                    ws.global_off_until = ws.global_off_until.max(until);
                    for i in 0..ws.live.len() {
                        if ws.live[i] {
                            ws.cis_off_until[i] = ws.cis_off_until[i].max(until);
                        }
                    }
                }
                PageSet::Pages(list) => {
                    for &i in list {
                        if i < ws.live.len() && ws.live[i] {
                            ws.cis_off_until[i] = ws.cis_off_until[i].max(until);
                        } else {
                            ws.stats.skipped_events += 1;
                        }
                    }
                }
            }
            ws.stats.outages += 1;
            trace::emit(tr, || TraceEvent::World {
                t: tw,
                kind: world_kind::OUTAGE,
                // page = first named slot; u32::MAX marks a global blackout
                page: match pages {
                    PageSet::All => u32::MAX,
                    PageSet::Pages(list) => list.first().map_or(u32::MAX, |&p| p as u32),
                },
            });
        }
        // folded into the effective bandwidth schedule before the run
        WorldEvent::BandwidthChange { .. } => {}
    }
}

/// Run one repetition of `scheduler` against `traces` under the
/// scripted `scenario` (throwaway workspace — repetition loops should
/// allocate one [`ScenarioWorkspace`] and reuse it).
pub fn simulate_scenario(
    traces: &EventTraces,
    cfg: &SimConfig,
    scenario: &Scenario,
    scheduler: &mut dyn CrawlScheduler,
) -> SimResult {
    let mut ws = ScenarioWorkspace::new();
    simulate_scenario_with(&mut ws, traces, cfg, scenario, scheduler)
}

/// Run one repetition under a dynamic world, using caller-owned
/// scratch. `traces` covers the scenario's *initial* population
/// (generate them exactly as for the static engine); everything the
/// world spawns afterwards is generated internally from the scenario
/// seed.
pub fn simulate_scenario_with(
    ws: &mut ScenarioWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scenario: &Scenario,
    scheduler: &mut dyn CrawlScheduler,
) -> SimResult {
    simulate_scenario_served_core(ws, traces, cfg, scenario, scheduler, None, None)
}

/// [`simulate_scenario_with`] with a serving layer attached: user
/// requests interleave with world and trace events (world → trace →
/// serve at equal times), flash crowds hit whatever occupies the slot
/// at request time, and requests into retired slots count as dead
/// serves. Read results off the session afterwards.
pub fn simulate_scenario_served_with(
    ws: &mut ScenarioWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scenario: &Scenario,
    scheduler: &mut dyn CrawlScheduler,
    serving: &mut ServingSession,
) -> SimResult {
    simulate_scenario_served_core(ws, traces, cfg, scenario, scheduler, Some(serving), None)
}

/// [`simulate_scenario_served_with`] with both the serving layer and
/// the trace sink optional — the fully-general dynamic-world entry
/// point. `tr = None` is branch-for-branch the untraced engine (the
/// handle is only ever *read*; pinned by `tests/trace_parity.rs`).
pub fn simulate_scenario_traced_with(
    ws: &mut ScenarioWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scenario: &Scenario,
    scheduler: &mut dyn CrawlScheduler,
    serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) -> SimResult {
    simulate_scenario_served_core(ws, traces, cfg, scenario, scheduler, serving, tr)
}

/// The dynamic-world merge loop with an *optional* serving layer —
/// `None` (or empty traffic) is branch-for-branch the plain scenario
/// engine (zero extra RNG draws; pinned by `tests/serving_parity.rs`).
fn simulate_scenario_served_core(
    ws: &mut ScenarioWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scenario: &Scenario,
    scheduler: &mut dyn CrawlScheduler,
    mut serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) -> SimResult {
    let m0 = traces.pages.len();
    assert_eq!(
        m0,
        scenario.initial_pages().len(),
        "traces must cover the scenario's initial population"
    );
    ws.reset(&traces.pages);
    scheduler.on_start(m0);
    for (i, p) in ws.pages.iter().enumerate() {
        debug_assert!(
            p.changes.windows(2).all(|w| w[0] <= w[1])
                && p.cis.windows(2).all(|w| w[0] <= w[1])
                && p.requests.windows(2).all(|w| w[0] <= w[1]),
            "page {i}: per-page event streams must be sorted by time"
        );
    }
    for i in 0..m0 {
        push_next(&mut ws.heap, &ws.pages[i], &ws.cursors[i], i as u32, ws.stream_ver[i]);
    }

    let world = scenario.events();
    let mut wc = 0usize; // world-event cursor

    let mut fresh_hits = 0u64;
    let mut requests = 0u64;
    let mut ticks = 0u64;
    let mut ev_count = 0u64; // events applied (world + merge pops + serves)
    let mut timeline = Vec::new();
    let window = cfg.timeline_window.unwrap_or(0);
    let mut ring_pos = 0usize;
    let mut ring_fresh = 0usize;

    let effective = effective_bandwidth(&cfg.bandwidth, world);
    let segs = effective.segments();
    let mut seg = 0usize; // monotone segment cursor
    let mut t = 0.0f64;
    loop {
        while seg + 1 < segs.len() && segs[seg + 1].0 <= t {
            seg += 1;
        }
        let r = segs[seg].1;
        let next_tick = t + 1.0 / r;
        if next_tick > cfg.horizon {
            break;
        }
        // apply world + trace events up to (and including) the tick
        // time, in time order; world events precede trace events at
        // equal times (and keep script order among themselves); user
        // requests serve after both at exact ties
        let ev_t0 = trace::span_clock(tr);
        loop {
            let tw = world.get(wc).map(|e| e.t).unwrap_or(f64::INFINITY);
            let te = match ws.heap.peek() {
                Some(&Reverse((OrdF64(x), _, _, _))) => x,
                None => f64::INFINITY,
            };
            if tw <= next_tick && tw <= te {
                apply_world(
                    ws,
                    scheduler,
                    &world[wc],
                    wc,
                    scenario,
                    cfg.horizon,
                    serving.as_deref_mut(),
                    tr,
                );
                wc += 1;
                ev_count += 1;
                continue;
            }
            if let Some(sv) = serving.as_deref_mut() {
                let ts = sv.next_time();
                if ts <= next_tick && ts < te && ts < tw {
                    let (st, sp) = sv.pop().expect("pending request");
                    let live = sp < ws.live.len() && ws.live[sp];
                    let fresh = sv.serve(sp, st, live);
                    ev_count += 1;
                    trace::emit(tr, || TraceEvent::Serve {
                        t: st,
                        page: sp as u32,
                        fresh: fresh == Some(true),
                        live: fresh.is_some(),
                    });
                    continue;
                }
            }
            if te > next_tick {
                break;
            }
            let Some(Reverse((OrdF64(et), kind, page, ver))) = ws.heap.pop() else {
                break; // unreachable: a finite frontier implies a non-empty heap
            };
            let i = page as usize;
            if ver != ws.stream_ver[i] {
                continue; // stale entry: the page retired or regenerated
            }
            ev_count += 1;
            match kind {
                KIND_CHANGE => {
                    ws.changed[i] = true;
                    ws.cursors[i][0] += 1;
                    if let Some(sv) = serving.as_deref_mut() {
                        sv.on_change(i, et);
                    }
                }
                KIND_REQUEST => {
                    requests += 1;
                    let fresh = !ws.changed[i];
                    if fresh {
                        fresh_hits += 1;
                    }
                    if window > 0 {
                        if ws.ring.len() < window {
                            ws.ring.push(fresh);
                            if fresh {
                                ring_fresh += 1;
                            }
                        } else {
                            if ws.ring[ring_pos] {
                                ring_fresh -= 1;
                            }
                            ws.ring[ring_pos] = fresh;
                            if fresh {
                                ring_fresh += 1;
                            }
                            ring_pos = (ring_pos + 1) % window;
                        }
                    }
                    ws.cursors[i][2] += 1;
                }
                _ => {
                    // KIND_CIS — outage suppression is checked FIRST
                    // (the streamed engine's rule, which filters at
                    // the source boundary before the discard window
                    // can see the delivery), so `cis_suppressed`
                    // counts identically in both trace modes
                    if et < ws.cis_off_until[i] {
                        ws.stats.cis_suppressed += 1;
                    } else {
                        let keep = match cfg.cis_discard_window {
                            Some(w) => et - ws.last_crawl[i] >= w,
                            None => true,
                        };
                        if keep {
                            scheduler.on_cis(i, et);
                            trace::emit(tr, || TraceEvent::Cis { t: et, page });
                        }
                    }
                    ws.cursors[i][1] += 1;
                }
            }
            push_next(&mut ws.heap, &ws.pages[i], &ws.cursors[i], page, ver);
        }
        trace::span_observe(tr, SpanKind::Events, ev_t0);
        // crawl at the tick
        t = next_tick;
        ticks += 1;
        let sel_t0 = trace::span_clock(tr);
        let pick = scheduler.select(t);
        trace::span_observe(tr, SpanKind::Select, sel_t0);
        if let Some(i) = pick {
            debug_assert!(i < ws.live.len());
            if ws.live[i] {
                let was_changed = ws.changed[i];
                scheduler.on_fetch_observed(i, t, was_changed);
                ws.changed[i] = false;
                ws.last_crawl[i] = t;
                ws.crawl_counts[i] += 1;
                scheduler.on_crawl(i, t);
                trace::emit(tr, || TraceEvent::Crawl { t, page: i as u32, changed: was_changed });
                if let Some(sv) = serving.as_deref_mut() {
                    sv.on_crawl(i);
                }
            } else {
                // the pick names a retired slot: forfeit the tick. A
                // hook-aware scheduler never does this (the parity
                // suite asserts stale_picks == 0); a hook-less one
                // (e.g. the LDS baseline, whose schedule predates the
                // churn) simply wastes the crawl — exactly what a
                // static plan does against a dead URL in production.
                ws.stats.stale_picks += 1;
                trace::emit(tr, || TraceEvent::Forfeit { t, page: i as u32 });
            }
        }
        trace::progress(tr, t, cfg.horizon, ev_count, ws.live.len() - ws.free.len());
        if window > 0 && !ws.ring.is_empty() {
            timeline.push((t, ring_fresh as f64 / ws.ring.len() as f64));
        }
    }
    // drain remaining events after the final tick: the world keeps
    // evolving UP TO the horizon (late births still contribute
    // requests); events scripted beyond it never happened in this run
    // — no hooks fire, no stats move
    loop {
        let tw = world.get(wc).map(|e| e.t).unwrap_or(f64::INFINITY);
        let te = match ws.heap.peek() {
            Some(&Reverse((OrdF64(x), _, _, _))) => x,
            None => f64::INFINITY,
        };
        if let Some(sv) = serving.as_deref_mut() {
            let ts = sv.next_time();
            if ts.is_finite() && ts < tw && ts < te {
                let (st, sp) = sv.pop().expect("pending request");
                let live = sp < ws.live.len() && ws.live[sp];
                let fresh = sv.serve(sp, st, live);
                trace::emit(tr, || TraceEvent::Serve {
                    t: st,
                    page: sp as u32,
                    fresh: fresh == Some(true),
                    live: fresh.is_some(),
                });
                continue;
            }
        }
        if wc < world.len() && tw <= te {
            if tw <= cfg.horizon {
                apply_world(
                    ws,
                    scheduler,
                    &world[wc],
                    wc,
                    scenario,
                    cfg.horizon,
                    serving.as_deref_mut(),
                    tr,
                );
            }
            wc += 1;
            continue;
        }
        let Some(Reverse((OrdF64(et), kind, page, ver))) = ws.heap.pop() else { break };
        let i = page as usize;
        if ver != ws.stream_ver[i] {
            continue;
        }
        match kind {
            KIND_CHANGE => {
                ws.changed[i] = true;
                ws.cursors[i][0] += 1;
                if let Some(sv) = serving.as_deref_mut() {
                    sv.on_change(i, et);
                }
            }
            KIND_REQUEST => {
                requests += 1;
                if !ws.changed[i] {
                    fresh_hits += 1;
                }
                ws.cursors[i][2] += 1;
            }
            _ => {
                ws.cursors[i][1] += 1;
            }
        }
        push_next(&mut ws.heap, &ws.pages[i], &ws.cursors[i], page, ver);
    }

    SimResult {
        accuracy: if requests > 0 { fresh_hits as f64 / requests as f64 } else { f64::NAN },
        requests,
        fresh_hits,
        crawl_counts: ws.crawl_counts.clone(),
        ticks,
        timeline,
    }
}

/// Streamed-mode next event of slot `i`, with **source-boundary outage
/// filtering**: deliveries already known to fall inside an outage
/// window are consumed and counted here, before they ever enter the
/// merge heap. (Outages declared *after* an event entered the frontier
/// are caught by the pop-time check in the main loop — the filter here
/// is the fast path, the pop-time check is the correctness backstop.)
#[inline]
fn next_streamed(
    ws: &mut ScenarioWorkspace,
    i: usize,
    horizon: f64,
    delay: CisDelay,
) -> Option<(f64, u8)> {
    loop {
        match ws.lazy[i].next(horizon, delay) {
            Some((t, k)) if k == KIND_CIS && t < ws.cis_off_until[i] => {
                ws.lazy[i].consume(KIND_CIS, horizon, delay);
                ws.stats.cis_suppressed += 1;
            }
            other => return other,
        }
    }
}

/// Apply one world event in streamed mode: births and parameter drift
/// **re-seed a [`PageEventSource`]** (from the same deterministic
/// `event_rng(seed, idx)` as the materialized path) instead of
/// regenerating a trace; quality shifts re-seed only the CIS substream
/// against the untouched change/request realization; retirement kills
/// the slot's source in place.
fn apply_world_streamed(
    ws: &mut ScenarioWorkspace,
    scheduler: &mut dyn CrawlScheduler,
    ev: &TimedEvent,
    idx: usize,
    scenario: &Scenario,
    horizon: f64,
    serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) {
    let tw = ev.t;
    let delay = scenario.delay();
    match &ev.event {
        WorldEvent::PageBorn { params } => {
            let mut rng = event_rng(scenario.seed(), idx);
            let source = PageEventSource::new(params, tw, horizon, delay, &mut rng);
            let slot = match ws.free.pop() {
                Some(s) => {
                    ws.generation[s] = ws.generation[s].wrapping_add(1);
                    ws.lazy[s] = source;
                    s
                }
                None => {
                    ws.lazy.push(source);
                    ws.grow_slot_columns()
                }
            };
            ws.live[slot] = true;
            ws.stream_ver[slot] = ws.stream_ver[slot].wrapping_add(1);
            ws.changed[slot] = false;
            ws.last_crawl[slot] = tw;
            // crawl_counts describe the slot's CURRENT occupant
            ws.crawl_counts[slot] = 0;
            // a global blackout covers newcomers; host-level outages
            // (explicit slot lists) cannot name the unborn
            ws.cis_off_until[slot] = ws.global_off_until;
            ws.stats.births += 1;
            trace::emit(tr, || TraceEvent::World { t: tw, kind: world_kind::BORN, page: slot as u32 });
            scheduler.on_page_added(slot, params, tw);
            if let Some(sv) = serving {
                sv.on_page_added(slot, params);
            }
            if let Some((t, k)) = next_streamed(ws, slot, horizon, delay) {
                ws.heap.push(Reverse((OrdF64(t), k, slot as u32, ws.stream_ver[slot])));
            }
        }
        WorldEvent::PageRetired { page } => {
            let i = *page;
            if i >= ws.live.len() || !ws.live[i] {
                ws.stats.skipped_events += 1;
                return;
            }
            ws.live[i] = false;
            ws.generation[i] = ws.generation[i].wrapping_add(1);
            // the pending heap entry dies with the version; the source
            // can never emit again
            ws.stream_ver[i] = ws.stream_ver[i].wrapping_add(1);
            ws.lazy[i].kill();
            ws.free.push(i);
            ws.stats.retirements += 1;
            trace::emit(tr, || TraceEvent::World { t: tw, kind: world_kind::RETIRED, page: i as u32 });
            scheduler.on_page_removed(i, tw);
        }
        WorldEvent::ParamsChanged { page, params } => {
            let i = *page;
            if i >= ws.live.len() || !ws.live[i] {
                ws.stats.skipped_events += 1;
                return;
            }
            // the applied past stays applied; the future is a fresh
            // source under the new parameters
            let mut rng = event_rng(scenario.seed(), idx);
            ws.lazy[i] = PageEventSource::new(params, tw, horizon, delay, &mut rng);
            ws.stream_ver[i] = ws.stream_ver[i].wrapping_add(1);
            ws.stats.param_shifts += 1;
            trace::emit(tr, || TraceEvent::World { t: tw, kind: world_kind::PARAMS, page: i as u32 });
            scheduler.on_params_changed(i, params, tw);
            if let Some((t, k)) = next_streamed(ws, i, horizon, delay) {
                ws.heap.push(Reverse((OrdF64(t), k, i as u32, ws.stream_ver[i])));
            }
        }
        WorldEvent::CisQualityShift { page, lam, nu } => {
            let i = *page;
            if i >= ws.live.len() || !ws.live[i] {
                ws.stats.skipped_events += 1;
                return;
            }
            // the change/request substreams and their next arrivals
            // are preserved (the future change realization is
            // untouched); in-flight deliveries of the old feed drop.
            // One boundary nuance vs the materialized path: the
            // already-rolled signal of the next not-yet-arrived change
            // drops with the buffer instead of being re-coined.
            let mut rng = event_rng(scenario.seed(), idx);
            ws.lazy[i].shift_cis_quality(*lam, *nu, tw, horizon, &mut rng);
            ws.stream_ver[i] = ws.stream_ver[i].wrapping_add(1);
            ws.stats.quality_shifts += 1;
            trace::emit(tr, || TraceEvent::World { t: tw, kind: world_kind::QUALITY, page: i as u32 });
            // the scheduler is NOT notified: its beliefs go stale
            if let Some((t, k)) = next_streamed(ws, i, horizon, delay) {
                ws.heap.push(Reverse((OrdF64(t), k, i as u32, ws.stream_ver[i])));
            }
        }
        WorldEvent::CisOutage { pages, duration } => {
            let until = tw + duration;
            match pages {
                PageSet::All => {
                    ws.global_off_until = ws.global_off_until.max(until);
                    for i in 0..ws.live.len() {
                        if ws.live[i] {
                            ws.cis_off_until[i] = ws.cis_off_until[i].max(until);
                        }
                    }
                }
                PageSet::Pages(list) => {
                    for &i in list {
                        if i < ws.live.len() && ws.live[i] {
                            ws.cis_off_until[i] = ws.cis_off_until[i].max(until);
                        } else {
                            ws.stats.skipped_events += 1;
                        }
                    }
                }
            }
            ws.stats.outages += 1;
            trace::emit(tr, || TraceEvent::World {
                t: tw,
                kind: world_kind::OUTAGE,
                // page = first named slot; u32::MAX marks a global blackout
                page: match pages {
                    PageSet::All => u32::MAX,
                    PageSet::Pages(list) => list.first().map_or(u32::MAX, |&p| p as u32),
                },
            });
        }
        // folded into the effective bandwidth schedule before the run
        WorldEvent::BandwidthChange { .. } => {}
    }
}

/// [`simulate_scenario_streamed_with`] with a throwaway workspace.
pub fn simulate_scenario_streamed(
    cfg: &SimConfig,
    scenario: &Scenario,
    trace_seed: u64,
    scheduler: &mut dyn CrawlScheduler,
) -> crate::Result<SimResult> {
    let mut ws = ScenarioWorkspace::new();
    simulate_scenario_streamed_with(&mut ws, cfg, scenario, trace_seed, scheduler)
}

/// Run one repetition under a dynamic world with **lazy event
/// sourcing**: the initial population's streams are per-page
/// [`PageEventSource`]s built from `trace_seed` (same per-page master
/// keying as the materialized entry point's `generate_traces`), and
/// world events re-seed sources instead of regenerating traces — the
/// whole run is `O(population)` memory. The world-event interleaving,
/// slot recycling, stream versioning and crawl accounting are the same
/// as [`simulate_scenario_with`]; the realization differs (lazy
/// substreams), so results are distribution-equal, not bit-equal, to
/// the materialized path.
pub fn simulate_scenario_streamed_with(
    ws: &mut ScenarioWorkspace,
    cfg: &SimConfig,
    scenario: &Scenario,
    trace_seed: u64,
    scheduler: &mut dyn CrawlScheduler,
) -> crate::Result<SimResult> {
    simulate_scenario_streamed_served_core(ws, cfg, scenario, trace_seed, scheduler, None, None)
}

/// [`simulate_scenario_streamed_with`] with a serving layer attached
/// (see [`simulate_scenario_served_with`] for the interleaving and
/// dead-slot semantics).
pub fn simulate_scenario_streamed_served_with(
    ws: &mut ScenarioWorkspace,
    cfg: &SimConfig,
    scenario: &Scenario,
    trace_seed: u64,
    scheduler: &mut dyn CrawlScheduler,
    serving: &mut ServingSession,
) -> crate::Result<SimResult> {
    simulate_scenario_streamed_served_core(
        ws,
        cfg,
        scenario,
        trace_seed,
        scheduler,
        Some(serving),
        None,
    )
}

/// [`simulate_scenario_streamed_with`] with both the serving layer and
/// the trace sink optional (see [`simulate_scenario_traced_with`] for
/// the `tr = None` parity guarantee).
pub fn simulate_scenario_streamed_traced_with(
    ws: &mut ScenarioWorkspace,
    cfg: &SimConfig,
    scenario: &Scenario,
    trace_seed: u64,
    scheduler: &mut dyn CrawlScheduler,
    serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) -> crate::Result<SimResult> {
    simulate_scenario_streamed_served_core(ws, cfg, scenario, trace_seed, scheduler, serving, tr)
}

/// Streamed dynamic-world merge loop with an *optional* serving layer
/// (`None` / empty traffic is branch-for-branch the plain streamed
/// scenario engine with zero extra RNG draws).
fn simulate_scenario_streamed_served_core(
    ws: &mut ScenarioWorkspace,
    cfg: &SimConfig,
    scenario: &Scenario,
    trace_seed: u64,
    scheduler: &mut dyn CrawlScheduler,
    mut serving: Option<&mut ServingSession>,
    tr: Option<&crate::trace::TraceHandle>,
) -> crate::Result<SimResult> {
    scenario.delay().validate()?;
    let delay = scenario.delay();
    let m0 = scenario.initial_pages().len();
    ws.reset_streamed(scenario, cfg.horizon, trace_seed);
    scheduler.on_start(m0);
    for i in 0..m0 {
        if let Some((t, k)) = next_streamed(ws, i, cfg.horizon, delay) {
            ws.heap.push(Reverse((OrdF64(t), k, i as u32, ws.stream_ver[i])));
        }
    }

    let world = scenario.events();
    let mut wc = 0usize; // world-event cursor

    let mut fresh_hits = 0u64;
    let mut requests = 0u64;
    let mut ticks = 0u64;
    let mut ev_count = 0u64; // events applied (world + merge pops + serves)
    let mut timeline = Vec::new();
    let window = cfg.timeline_window.unwrap_or(0);
    let mut ring_pos = 0usize;
    let mut ring_fresh = 0usize;

    let effective = effective_bandwidth(&cfg.bandwidth, world);
    let segs = effective.segments();
    let mut seg = 0usize; // monotone segment cursor
    let mut t = 0.0f64;
    loop {
        while seg + 1 < segs.len() && segs[seg + 1].0 <= t {
            seg += 1;
        }
        let r = segs[seg].1;
        let next_tick = t + 1.0 / r;
        if next_tick > cfg.horizon {
            break;
        }
        // world + trace events up to (and including) the tick time, in
        // time order; world events precede trace events at equal
        // times; user requests serve after both at exact ties
        let ev_t0 = trace::span_clock(tr);
        loop {
            let tw = world.get(wc).map(|e| e.t).unwrap_or(f64::INFINITY);
            let te = match ws.heap.peek() {
                Some(&Reverse((OrdF64(x), _, _, _))) => x,
                None => f64::INFINITY,
            };
            if tw <= next_tick && tw <= te {
                apply_world_streamed(
                    ws,
                    scheduler,
                    &world[wc],
                    wc,
                    scenario,
                    cfg.horizon,
                    serving.as_deref_mut(),
                    tr,
                );
                wc += 1;
                ev_count += 1;
                continue;
            }
            if let Some(sv) = serving.as_deref_mut() {
                let ts = sv.next_time();
                if ts <= next_tick && ts < te && ts < tw {
                    let (st, sp) = sv.pop().expect("pending request");
                    let live = sp < ws.live.len() && ws.live[sp];
                    let fresh = sv.serve(sp, st, live);
                    ev_count += 1;
                    trace::emit(tr, || TraceEvent::Serve {
                        t: st,
                        page: sp as u32,
                        fresh: fresh == Some(true),
                        live: fresh.is_some(),
                    });
                    continue;
                }
            }
            if te > next_tick {
                break;
            }
            let Some(Reverse((OrdF64(et), kind, page, ver))) = ws.heap.pop() else {
                break; // unreachable: a finite frontier implies a non-empty heap
            };
            let i = page as usize;
            if ver != ws.stream_ver[i] {
                continue; // stale entry: the page retired or re-seeded
            }
            ev_count += 1;
            match kind {
                KIND_CHANGE => {
                    ws.changed[i] = true;
                    if let Some(sv) = serving.as_deref_mut() {
                        sv.on_change(i, et);
                    }
                }
                KIND_REQUEST => {
                    requests += 1;
                    let fresh = !ws.changed[i];
                    if fresh {
                        fresh_hits += 1;
                    }
                    if window > 0 {
                        if ws.ring.len() < window {
                            ws.ring.push(fresh);
                            if fresh {
                                ring_fresh += 1;
                            }
                        } else {
                            if ws.ring[ring_pos] {
                                ring_fresh -= 1;
                            }
                            ws.ring[ring_pos] = fresh;
                            if fresh {
                                ring_fresh += 1;
                            }
                            ring_pos = (ring_pos + 1) % window;
                        }
                    }
                }
                _ => {
                    // KIND_CIS — pop-time backstop for outages declared
                    // after this delivery entered the frontier
                    if et < ws.cis_off_until[i] {
                        ws.stats.cis_suppressed += 1;
                    } else {
                        let keep = match cfg.cis_discard_window {
                            Some(w) => et - ws.last_crawl[i] >= w,
                            None => true,
                        };
                        if keep {
                            scheduler.on_cis(i, et);
                            trace::emit(tr, || TraceEvent::Cis { t: et, page });
                        }
                    }
                }
            }
            ws.lazy[i].consume(kind, cfg.horizon, delay);
            if let Some((nt, nk)) = next_streamed(ws, i, cfg.horizon, delay) {
                ws.heap.push(Reverse((OrdF64(nt), nk, page, ver)));
            }
        }
        trace::span_observe(tr, SpanKind::Events, ev_t0);
        // crawl at the tick
        t = next_tick;
        ticks += 1;
        let sel_t0 = trace::span_clock(tr);
        let pick = scheduler.select(t);
        trace::span_observe(tr, SpanKind::Select, sel_t0);
        if let Some(i) = pick {
            debug_assert!(i < ws.live.len());
            if ws.live[i] {
                let was_changed = ws.changed[i];
                scheduler.on_fetch_observed(i, t, was_changed);
                ws.changed[i] = false;
                ws.last_crawl[i] = t;
                ws.crawl_counts[i] += 1;
                scheduler.on_crawl(i, t);
                trace::emit(tr, || TraceEvent::Crawl { t, page: i as u32, changed: was_changed });
                if let Some(sv) = serving.as_deref_mut() {
                    sv.on_crawl(i);
                }
            } else {
                ws.stats.stale_picks += 1;
                trace::emit(tr, || TraceEvent::Forfeit { t, page: i as u32 });
            }
        }
        trace::progress(tr, t, cfg.horizon, ev_count, ws.live.len() - ws.free.len());
        if window > 0 && !ws.ring.is_empty() {
            timeline.push((t, ring_fresh as f64 / ws.ring.len() as f64));
        }
    }
    // drain remaining events after the final tick: the world keeps
    // evolving UP TO the horizon; events scripted beyond it never
    // happened in this run
    loop {
        let tw = world.get(wc).map(|e| e.t).unwrap_or(f64::INFINITY);
        let te = match ws.heap.peek() {
            Some(&Reverse((OrdF64(x), _, _, _))) => x,
            None => f64::INFINITY,
        };
        if let Some(sv) = serving.as_deref_mut() {
            let ts = sv.next_time();
            if ts.is_finite() && ts < tw && ts < te {
                let (st, sp) = sv.pop().expect("pending request");
                let live = sp < ws.live.len() && ws.live[sp];
                let fresh = sv.serve(sp, st, live);
                trace::emit(tr, || TraceEvent::Serve {
                    t: st,
                    page: sp as u32,
                    fresh: fresh == Some(true),
                    live: fresh.is_some(),
                });
                continue;
            }
        }
        if wc < world.len() && tw <= te {
            if tw <= cfg.horizon {
                apply_world_streamed(
                    ws,
                    scheduler,
                    &world[wc],
                    wc,
                    scenario,
                    cfg.horizon,
                    serving.as_deref_mut(),
                    tr,
                );
            }
            wc += 1;
            continue;
        }
        let Some(Reverse((OrdF64(et), kind, page, ver))) = ws.heap.pop() else { break };
        let i = page as usize;
        if ver != ws.stream_ver[i] {
            continue;
        }
        match kind {
            KIND_CHANGE => {
                ws.changed[i] = true;
                if let Some(sv) = serving.as_deref_mut() {
                    sv.on_change(i, et);
                }
            }
            KIND_REQUEST => {
                requests += 1;
                if !ws.changed[i] {
                    fresh_hits += 1;
                }
            }
            _ => {}
        }
        ws.lazy[i].consume(kind, cfg.horizon, delay);
        if let Some((nt, nk)) = next_streamed(ws, i, cfg.horizon, delay) {
            ws.heap.push(Reverse((OrdF64(nt), nk, page, ver)));
        }
    }

    Ok(SimResult {
        accuracy: if requests > 0 { fresh_hits as f64 / requests as f64 } else { f64::NAN },
        requests,
        fresh_hits,
        crawl_counts: ws.crawl_counts.clone(),
        ticks,
        timeline,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::params::PageParams;
    use crate::rngkit::Rng;
    use crate::sched::PageTracker;
    use crate::sim::{generate_traces, simulate, CisDelay};

    fn pages(m: usize, seed: u64) -> Vec<PageParams> {
        let mut rng = Rng::new(seed);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.5),
            })
            .collect()
    }

    /// Deterministic state-dependent scheduler with full dynamic-hook
    /// support (mirrors the engine tests' `StateScore`).
    struct StateScore {
        tracker: PageTracker,
        live: Vec<bool>,
    }
    impl StateScore {
        fn new() -> Self {
            Self { tracker: PageTracker::default(), live: Vec::new() }
        }
    }
    impl CrawlScheduler for StateScore {
        fn on_start(&mut self, m: usize) {
            self.tracker.reset(m);
            self.live.clear();
            self.live.resize(m, true);
        }
        fn on_cis(&mut self, page: usize, _t: f64) {
            self.tracker.on_cis(page);
        }
        fn on_crawl(&mut self, page: usize, t: f64) {
            self.tracker.on_crawl(page, t);
        }
        fn on_page_added(&mut self, page: usize, _params: &PageParams, t: f64) {
            self.tracker.add_page(page, t);
            if page == self.live.len() {
                self.live.push(true);
            } else {
                self.live[page] = true;
            }
        }
        fn on_page_removed(&mut self, page: usize, _t: f64) {
            self.tracker.remove_page(page);
            self.live[page] = false;
        }
        fn select(&mut self, t: f64) -> Option<usize> {
            let mut best = f64::NEG_INFINITY;
            let mut arg = None;
            for i in 0..self.tracker.len() {
                if !self.live[i] {
                    continue;
                }
                let v = self.tracker.tau_elap(i, t) + 3.7 * self.tracker.n_cis(i) as f64;
                if v > best {
                    best = v;
                    arg = Some(i);
                }
            }
            arg
        }
    }

    #[test]
    fn empty_scenario_matches_static_engine() {
        let ps = pages(25, 1);
        let mut rng = Rng::new(2);
        let traces = generate_traces(&ps, 40.0, CisDelay::None, &mut rng);
        let mut cfg = SimConfig::new(4.0, 40.0).unwrap();
        cfg.timeline_window = Some(16);
        cfg.cis_discard_window = Some(0.15);
        let sc = Scenario::new(ps, 9);
        let a = simulate(&traces, &cfg, &mut StateScore::new());
        let b = simulate_scenario(&traces, &cfg, &sc, &mut StateScore::new());
        assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits());
        assert_eq!(a.requests, b.requests);
        assert_eq!(a.crawl_counts, b.crawl_counts);
        assert_eq!(a.ticks, b.ticks);
        assert_eq!(a.timeline, b.timeline);
    }

    #[test]
    fn retirement_frees_and_birth_recycles_with_generation_bumps() {
        let ps = pages(4, 3);
        let newcomer = PageParams { delta: 0.9, mu: 0.9, lam: 0.5, nu: 0.1 };
        let sc = Scenario::new(ps.clone(), 7)
            .at(5.0, WorldEvent::PageRetired { page: 2 })
            .at(10.0, WorldEvent::PageBorn { params: newcomer });
        let mut rng = Rng::new(4);
        let traces = generate_traces(&ps, 20.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(2.0, 20.0).unwrap();
        let mut ws = ScenarioWorkspace::new();
        let res = simulate_scenario_with(&mut ws, &traces, &cfg, &sc, &mut StateScore::new());
        assert_eq!(ws.stats.births, 1);
        assert_eq!(ws.stats.retirements, 1);
        assert_eq!(ws.stats.skipped_events, 0);
        assert_eq!(ws.stats.stale_picks, 0);
        // LIFO recycling: the birth reuses slot 2, two transitions deep
        assert_eq!(ws.population(), 4, "birth must recycle the freed slot");
        assert!(ws.is_live(2));
        assert_eq!(ws.generation(2), 2);
        assert!((0.0..=1.0).contains(&res.accuracy));
    }

    #[test]
    fn outage_suppresses_cis_only_inside_window() {
        // one page, CIS guaranteed by lam=1, outage [5, 10)
        let ps = vec![PageParams { delta: 1.0, mu: 0.3, lam: 1.0, nu: 0.5 }];
        let sc = Scenario::new(ps.clone(), 11).at(
            5.0,
            WorldEvent::CisOutage { pages: PageSet::All, duration: 5.0 },
        );
        let mut rng = Rng::new(5);
        let traces = generate_traces(&ps, 20.0, CisDelay::None, &mut rng);
        let in_window =
            traces.pages[0].cis.iter().filter(|&&c| (5.0..10.0).contains(&c)).count() as u64;
        let total = traces.pages[0].cis.len() as u64;
        assert!(in_window > 0, "test needs CIS inside the window");

        struct CountCis(u64);
        impl CrawlScheduler for CountCis {
            fn on_cis(&mut self, _page: usize, _t: f64) {
                self.0 += 1;
            }
            fn select(&mut self, _t: f64) -> Option<usize> {
                Some(0)
            }
        }
        let cfg = SimConfig::new(1.0, 20.0).unwrap();
        let mut ws = ScenarioWorkspace::new();
        let mut s = CountCis(0);
        simulate_scenario_with(&mut ws, &traces, &cfg, &sc, &mut s);
        assert_eq!(ws.stats.cis_suppressed, in_window);
        assert_eq!(s.0, total - in_window, "outside-window CIS must still deliver");
    }

    #[test]
    fn suppression_counting_is_mode_identical() {
        // a guaranteed-signal page under a full-horizon blackout AND a
        // discard window: every CIS delivery is in-outage, so both
        // engines must count every one as suppressed — the materialized
        // path must not let the discard window swallow deliveries
        // before the suppression counter sees them
        let ps = vec![PageParams { delta: 1.0, mu: 0.3, lam: 1.0, nu: 0.5 }];
        let sc = Scenario::new(ps.clone(), 11).at(
            0.0,
            WorldEvent::CisOutage { pages: PageSet::All, duration: 20.0 },
        );
        struct AlwaysZero;
        impl CrawlScheduler for AlwaysZero {
            fn select(&mut self, _t: f64) -> Option<usize> {
                Some(0)
            }
        }
        let mut cfg = SimConfig::new(1.0, 20.0).unwrap();
        // an aggressive discard window that would (before the fix)
        // hide most in-outage deliveries from the materialized counter
        cfg.cis_discard_window = Some(5.0);
        let mut rng = Rng::new(5);
        let traces = generate_traces(&ps, 20.0, CisDelay::None, &mut rng);
        let total = traces.pages[0].cis.iter().filter(|&&c| c < 20.0).count() as u64;
        assert!(total > 0, "test needs CIS deliveries inside the blackout");
        let mut mat = ScenarioWorkspace::new();
        simulate_scenario_with(&mut mat, &traces, &cfg, &sc, &mut AlwaysZero);
        assert_eq!(
            mat.stats.cis_suppressed, total,
            "materialized: every in-outage CIS counts, discard window or not"
        );
        // the streamed realization is a different draw, but its rule
        // is the same: every delivered-before-horizon CIS is in-outage
        // and must be counted
        let mut st = ScenarioWorkspace::new();
        simulate_scenario_streamed_with(&mut st, &cfg, &sc, 5, &mut AlwaysZero).unwrap();
        assert!(st.stats.cis_suppressed > 0);
        // and with no discard window the materialized count is
        // unchanged — suppression is independent of the window
        let mut cfg2 = SimConfig::new(1.0, 20.0).unwrap();
        cfg2.cis_discard_window = None;
        let mut mat2 = ScenarioWorkspace::new();
        simulate_scenario_with(&mut mat2, &traces, &cfg2, &sc, &mut AlwaysZero);
        assert_eq!(mat2.stats.cis_suppressed, mat.stats.cis_suppressed);
    }

    #[test]
    fn newborn_inherits_an_active_global_blackout() {
        // blackout over [5, 15); a CIS-firehose page is born at t=8:
        // its deliveries stay dark until the blackout lifts
        let ps = vec![PageParams { delta: 0.2, mu: 0.2, lam: 0.0, nu: 0.0 }];
        let loud = PageParams { delta: 1.0, mu: 0.2, lam: 1.0, nu: 1.0 };
        let sc = Scenario::new(ps.clone(), 31)
            .at(5.0, WorldEvent::CisOutage { pages: PageSet::All, duration: 10.0 })
            .at(8.0, WorldEvent::PageBorn { params: loud });
        struct CisLog(Vec<(usize, f64)>);
        impl CrawlScheduler for CisLog {
            fn on_cis(&mut self, page: usize, t: f64) {
                self.0.push((page, t));
            }
            fn select(&mut self, _t: f64) -> Option<usize> {
                None
            }
        }
        let mut rng = Rng::new(32);
        let traces = generate_traces(&ps, 30.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(1.0, 30.0).unwrap();
        let mut ws = ScenarioWorkspace::new();
        let mut s = CisLog(Vec::new());
        simulate_scenario_with(&mut ws, &traces, &cfg, &sc, &mut s);
        let newborn_cis: Vec<f64> =
            s.0.iter().filter(|&&(p, _)| p == 1).map(|&(_, t)| t).collect();
        assert!(!newborn_cis.is_empty(), "the firehose must deliver after the blackout");
        assert!(
            newborn_cis.iter().all(|&t| t >= 15.0),
            "newborn CIS leaked through the blackout: {newborn_cis:?}"
        );
        assert!(ws.stats.cis_suppressed > 0, "the blackout must have suppressed something");
    }

    #[test]
    fn params_changed_regenerates_only_the_future() {
        // page becomes a non-changer at t=10: all post-shift requests
        // hit fresh content once the page is crawled after the shift
        let ps = vec![PageParams { delta: 2.0, mu: 2.0, lam: 0.0, nu: 0.0 }];
        let frozen = PageParams { delta: 1e-9, mu: 2.0, lam: 0.0, nu: 0.0 };
        let sc = Scenario::new(ps.clone(), 13)
            .at(10.0, WorldEvent::ParamsChanged { page: 0, params: frozen });
        let mut rng = Rng::new(6);
        let traces = generate_traces(&ps, 40.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(1.0, 40.0).unwrap();
        let mut ws = ScenarioWorkspace::new();
        let res = simulate_scenario_with(&mut ws, &traces, &cfg, &sc, &mut StateScore::new());
        assert_eq!(ws.stats.param_shifts, 1);
        // with Δ ≈ 0 after t=10 and a crawl every tick, the page is
        // permanently fresh shortly after the shift
        assert!(res.accuracy > 0.5, "accuracy {}", res.accuracy);
    }

    #[test]
    fn bandwidth_change_splices_into_schedule() {
        let ps = pages(2, 8);
        let sc = Scenario::new(ps.clone(), 17)
            .at(5.0, WorldEvent::BandwidthChange { rate: 10.0 });
        let mut rng = Rng::new(9);
        let traces = generate_traces(&ps, 10.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(1.0, 10.0).unwrap();
        let res = simulate_scenario(&traces, &cfg, &sc, &mut StateScore::new());
        // ~5 ticks at R=1, then ~50 at R=10
        assert!((res.ticks as i64 - 55).abs() <= 2, "{}", res.ticks);
    }

    #[test]
    fn events_on_dead_pages_are_counted_noops() {
        let ps = pages(2, 10);
        let sc = Scenario::new(ps.clone(), 21)
            .at(2.0, WorldEvent::PageRetired { page: 1 })
            .at(3.0, WorldEvent::PageRetired { page: 1 }) // already dead
            .at(4.0, WorldEvent::ParamsChanged { page: 1, params: ps[0] })
            .at(5.0, WorldEvent::CisQualityShift { page: 9, lam: 0.5, nu: 0.1 });
        let mut rng = Rng::new(11);
        let traces = generate_traces(&ps, 10.0, CisDelay::None, &mut rng);
        let cfg = SimConfig::new(2.0, 10.0).unwrap();
        let mut ws = ScenarioWorkspace::new();
        simulate_scenario_with(&mut ws, &traces, &cfg, &sc, &mut StateScore::new());
        assert_eq!(ws.stats.retirements, 1);
        assert_eq!(ws.stats.skipped_events, 3);
    }

    #[test]
    fn streamed_scenario_is_deterministic_under_full_churn() {
        // births + retirements + drift + quality shift + outage, all
        // through the lazy path: stats must replay bit-identically and
        // the slot audit must hold
        let ps = pages(20, 40);
        let newcomer = PageParams { delta: 0.9, mu: 0.9, lam: 0.5, nu: 0.1 };
        let sc = Scenario::new(ps.clone(), 41)
            .at(3.0, WorldEvent::PageRetired { page: 5 })
            .at(6.0, WorldEvent::PageBorn { params: newcomer })
            .at(8.0, WorldEvent::ParamsChanged { page: 2, params: newcomer })
            .at(10.0, WorldEvent::CisQualityShift { page: 3, lam: 0.9, nu: 0.05 })
            .at(12.0, WorldEvent::CisOutage { pages: PageSet::All, duration: 4.0 })
            .at(20.0, WorldEvent::PageBorn { params: newcomer });
        let cfg = SimConfig::new(3.0, 40.0).unwrap();
        let run = || {
            let mut ws = ScenarioWorkspace::new();
            let res = simulate_scenario_streamed_with(
                &mut ws,
                &cfg,
                &sc,
                77,
                &mut StateScore::new(),
            )
            .unwrap();
            (res, ws.stats)
        };
        let (r1, s1) = run();
        let (r2, s2) = run();
        assert_eq!(r1.accuracy.to_bits(), r2.accuracy.to_bits());
        assert_eq!(r1.requests, r2.requests);
        assert_eq!(r1.crawl_counts, r2.crawl_counts);
        assert_eq!(s1, s2, "streamed world stats diverged between replays");
        assert_eq!(s1.births, 2);
        assert_eq!(s1.retirements, 1);
        assert_eq!(s1.param_shifts, 1);
        assert_eq!(s1.quality_shifts, 1);
        assert_eq!(s1.outages, 1);
        assert_eq!(s1.stale_picks, 0);
        assert_eq!(s1.skipped_events, 0);
        assert!((0.0..=1.0).contains(&r1.accuracy));
        // LIFO recycling: the first birth reuses the retired slot 5
        let mut ws = ScenarioWorkspace::new();
        let _ =
            simulate_scenario_streamed_with(&mut ws, &cfg, &sc, 77, &mut StateScore::new())
                .unwrap();
        assert_eq!(ws.population(), 21, "second birth must grow the population");
        assert!(ws.is_live(5));
        assert_eq!(ws.generation(5), 2);
    }

    #[test]
    fn streamed_outage_filters_at_the_source() {
        // one guaranteed-signal page, outage [5, 10): nothing may be
        // delivered inside the window, deliveries resume after
        let ps = vec![PageParams { delta: 1.0, mu: 0.3, lam: 1.0, nu: 0.5 }];
        let sc = Scenario::new(ps.clone(), 11).at(
            5.0,
            WorldEvent::CisOutage { pages: PageSet::All, duration: 5.0 },
        );
        struct CisLog(Vec<f64>);
        impl CrawlScheduler for CisLog {
            fn on_cis(&mut self, _page: usize, t: f64) {
                self.0.push(t);
            }
            fn select(&mut self, _t: f64) -> Option<usize> {
                Some(0)
            }
        }
        let cfg = SimConfig::new(1.0, 20.0).unwrap();
        let mut ws = ScenarioWorkspace::new();
        let mut s = CisLog(Vec::new());
        simulate_scenario_streamed_with(&mut ws, &cfg, &sc, 13, &mut s).unwrap();
        assert!(!s.0.is_empty(), "deliveries outside the window expected");
        assert!(
            s.0.iter().all(|&t| !(5.0..10.0).contains(&t)),
            "CIS leaked through the outage window: {:?}",
            s.0
        );
        assert!(s.0.iter().any(|&t| t >= 10.0), "feed must resume after the outage");
        assert!(ws.stats.cis_suppressed > 0, "the window must have suppressed something");
    }

    #[test]
    fn streamed_quality_shift_preserves_changes_and_requests() {
        // λ: 0 → 1 at t=10 with ν staying 0: before the shift no CIS
        // at all, after it (almost) every change signals instantly
        let ps = vec![PageParams { delta: 1.0, mu: 0.5, lam: 0.0, nu: 0.0 }];
        let sc = Scenario::new(ps.clone(), 19)
            .at(10.0, WorldEvent::CisQualityShift { page: 0, lam: 1.0, nu: 0.0 });
        struct CisLog(Vec<f64>);
        impl CrawlScheduler for CisLog {
            fn on_cis(&mut self, _page: usize, t: f64) {
                self.0.push(t);
            }
            fn select(&mut self, _t: f64) -> Option<usize> {
                None
            }
        }
        let cfg = SimConfig::new(1.0, 60.0).unwrap();
        let mut ws = ScenarioWorkspace::new();
        let mut s = CisLog(Vec::new());
        let res = simulate_scenario_streamed_with(&mut ws, &cfg, &sc, 23, &mut s).unwrap();
        assert_eq!(ws.stats.quality_shifts, 1);
        assert!(s.0.iter().all(|&t| t >= 10.0), "λ=0 before the shift: {:?}", s.0);
        assert!(!s.0.is_empty(), "λ=1 after the shift must deliver signals");
        // requests kept flowing the whole run (their substream is
        // untouched by the shift)
        assert!(res.requests > 0);
    }

    #[test]
    fn effective_bandwidth_latest_directive_wins() {
        let base = BandwidthSchedule::new(vec![(0.0, 1.0), (10.0, 4.0)]).unwrap();
        let sc = Scenario::new(pages(1, 12), 1)
            .at(5.0, WorldEvent::BandwidthChange { rate: 2.0 })
            .at(10.0, WorldEvent::BandwidthChange { rate: 8.0 });
        let eff = effective_bandwidth(&base, sc.events());
        assert_eq!(eff.rate_at(1.0), 1.0);
        assert_eq!(eff.rate_at(6.0), 2.0);
        // at t=10 both a base segment and a scenario change start: the
        // scenario directive wins
        assert_eq!(eff.rate_at(10.0), 8.0);
        // no changes → the base schedule verbatim
        let none = Scenario::new(pages(1, 12), 1);
        assert_eq!(effective_bandwidth(&base, none.events()).segments(), base.segments());
    }
}
