//! Composable generators for canonical dynamic-world stress patterns.
//!
//! Each `add_*` function appends a deterministic event pattern to an
//! existing [`Scenario`] (its own RNG stream, seeded by the caller), so
//! patterns compose: churn + a correlated outage + a flash crowd is
//! three calls on one scenario. The churn generator models the
//! engine's LIFO slot recycling to always retire a *live* page; add
//! churn before other generators that reference page indices, and
//! check [`super::ScenarioStats::skipped_events`] stayed 0 when
//! composing aggressively.

use crate::coordinator::hosts::host_of;
use crate::params::PageParams;
use crate::rngkit::{self, Rng};
use crate::scenario::{PageSet, Scenario, WorldEvent};

/// Parameter distribution for pages born by the churn generator —
/// mirrors `figures::common::ExperimentSpec`'s §6.3 draws.
#[derive(Debug, Clone, Copy)]
pub struct BornPageSpec {
    /// Δ, μ ~ U(lo, hi).
    pub delta_range: (f64, f64),
    /// Importance range.
    pub mu_range: (f64, f64),
    /// λ ~ Beta(a, b) when set, else λ = 0.
    pub lam_beta: Option<(f64, f64)>,
    /// ν ~ U(lo, hi) when set, else ν = 0.
    pub nu_range: Option<(f64, f64)>,
}

impl Default for BornPageSpec {
    fn default() -> Self {
        Self {
            delta_range: (1e-4, 1.0),
            mu_range: (1e-4, 1.0),
            lam_beta: Some((0.25, 0.25)),
            nu_range: Some((0.1, 0.6)),
        }
    }
}

impl BornPageSpec {
    /// Draw one page.
    pub fn sample(&self, rng: &mut Rng) -> PageParams {
        PageParams {
            delta: rng.range(self.delta_range.0, self.delta_range.1),
            mu: rng.range(self.mu_range.0, self.mu_range.1),
            lam: match self.lam_beta {
                Some((a, b)) => rngkit::beta(rng, a, b),
                None => 0.0,
            },
            nu: match self.nu_range {
                Some((lo, hi)) => rng.range(lo, hi),
                None => 0.0,
            },
        }
    }
}

/// Steady page churn at rate `rho` (fraction of the initial population
/// per unit time): churn events arrive as a Poisson process with rate
/// `rho · m₀` over `[0, horizon)`; each retires one uniformly-random
/// live page and births a replacement drawn from `born`, so the
/// population stays at `m₀` while its identity turns over. Retirement
/// precedes the birth at the same instant, so with the engine's LIFO
/// free list every churn birth recycles the just-freed slot —
/// maximizing pressure on the generation-counter audit.
pub fn add_steady_churn(
    sc: &mut Scenario,
    rho: f64,
    horizon: f64,
    born: &BornPageSpec,
    seed: u64,
) {
    assert!(rho >= 0.0 && rho.is_finite(), "churn rate must be >= 0, got {rho}");
    let m0 = sc.initial_pages().len();
    let mut rng = Rng::new(seed);
    let times = rngkit::poisson_process(&mut rng, rho * m0 as f64, horizon);
    // model the engine's slot assignment: retire-then-birth at the
    // same time means the birth always recycles the retired slot, so
    // the live set is always exactly {0, .., m0-1}
    let mut batch = Vec::with_capacity(2 * times.len());
    for t in times {
        let victim = rng.below(m0 as u64) as usize;
        batch.push((t, WorldEvent::PageRetired { page: victim }));
        batch.push((t, WorldEvent::PageBorn { params: born.sample(&mut rng) }));
    }
    sc.push_many(batch);
}

/// A flash crowd: at `t0` a random `frac` of the initial pages see
/// their request rate multiplied by `mu_factor` (and optionally their
/// change rate by `delta_factor` — breaking news changes *and* is
/// demanded more); at `t0 + duration` the affected pages revert to
/// their original parameters. Emitted as paired `ParamsChanged`
/// events, so schedulers are told (a surge is observable).
pub fn add_flash_crowd(
    sc: &mut Scenario,
    t0: f64,
    duration: f64,
    frac: f64,
    mu_factor: f64,
    delta_factor: f64,
    seed: u64,
) {
    assert!((0.0..=1.0).contains(&frac), "frac must be in [0,1], got {frac}");
    assert!(duration > 0.0, "duration must be > 0");
    let initial = sc.initial_pages().to_vec();
    let mut rng = Rng::new(seed);
    let k = ((initial.len() as f64) * frac).round() as usize;
    let chosen = rng.sample_indices(initial.len(), k.min(initial.len()));
    let mut batch = Vec::with_capacity(2 * chosen.len());
    for i in chosen {
        let base = initial[i];
        let surged = PageParams {
            mu: base.mu * mu_factor,
            delta: base.delta * delta_factor,
            ..base
        };
        batch.push((t0, WorldEvent::ParamsChanged { page: i, params: surged }));
        batch.push((t0 + duration, WorldEvent::ParamsChanged { page: i, params: base }));
    }
    sc.push_many(batch);
}

/// Diurnal drift: every `period / samples_per_cycle`, the change rates
/// of a random `frac` of the initial pages are re-pinned to
/// `Δᵢ · (1 + amplitude · sin(2π t / period))` — the day/night rhythm
/// of real corpora, piecewise-constant at the sample resolution.
/// Emitted as `ParamsChanged` (observable drift, as a re-estimation
/// pipeline would surface it).
pub fn add_diurnal_drift(
    sc: &mut Scenario,
    period: f64,
    amplitude: f64,
    samples_per_cycle: usize,
    frac: f64,
    horizon: f64,
    seed: u64,
) {
    assert!(period > 0.0 && samples_per_cycle > 0);
    assert!(amplitude > -1.0 && amplitude < 1.0, "amplitude must keep Δ > 0");
    let initial = sc.initial_pages().to_vec();
    let mut rng = Rng::new(seed);
    let k = ((initial.len() as f64) * frac).round() as usize;
    let chosen = rng.sample_indices(initial.len(), k.min(initial.len()));
    let dt = period / samples_per_cycle as f64;
    let mut batch = Vec::new();
    let mut t = dt;
    while t < horizon {
        let scale = 1.0 + amplitude * (2.0 * std::f64::consts::PI * t / period).sin();
        for &i in &chosen {
            let base = initial[i];
            batch.push((
                t,
                WorldEvent::ParamsChanged {
                    page: i,
                    params: PageParams { delta: base.delta * scale, ..base },
                },
            ));
        }
        t += dt;
    }
    sc.push_many(batch);
}

/// Correlated host-level CIS outages: pages are grouped into `hosts`
/// round-robin hosts (the shared
/// [`host_of`](crate::coordinator::hosts::host_of) convention), and
/// `n_outages` outage windows
/// (uniform start over the horizon, Exp(1/mean_duration) length) each
/// darken one whole host's ping feed at once — the realistic failure
/// unit: a sitemap endpoint or ping relay dies per site, not per URL.
pub fn add_correlated_outages(
    sc: &mut Scenario,
    hosts: usize,
    n_outages: usize,
    mean_duration: f64,
    horizon: f64,
    seed: u64,
) {
    assert!(hosts > 0 && mean_duration > 0.0);
    let m0 = sc.initial_pages().len();
    let mut rng = Rng::new(seed);
    let mut batch = Vec::with_capacity(n_outages);
    for _ in 0..n_outages {
        let t = rng.range(0.0, horizon);
        let h = rng.below(hosts as u64) as usize;
        let members: Vec<usize> = (0..m0).filter(|&i| host_of(i, hosts) == h).collect();
        let duration = rngkit::exponential(&mut rng, 1.0 / mean_duration);
        batch.push((t, WorldEvent::CisOutage { pages: PageSet::Pages(members), duration }));
    }
    sc.push_many(batch);
}

#[cfg(test)]
mod tests {
    use super::*;

    fn initial(m: usize) -> Vec<PageParams> {
        let mut rng = Rng::new(1);
        (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.0),
                mu: rng.range(0.05, 1.0),
                lam: 0.5,
                nu: 0.2,
            })
            .collect()
    }

    #[test]
    fn churn_pairs_retire_then_birth_and_replay_identically() {
        let mut a = Scenario::new(initial(50), 3);
        add_steady_churn(&mut a, 0.02, 100.0, &BornPageSpec::default(), 7);
        let mut b = Scenario::new(initial(50), 3);
        add_steady_churn(&mut b, 0.02, 100.0, &BornPageSpec::default(), 7);
        assert!(!a.is_static(), "expected churn events (rate 1/unit over 100 units)");
        assert_eq!(a.events().len(), b.events().len());
        for (x, y) in a.events().iter().zip(b.events()) {
            assert_eq!(x.t.to_bits(), y.t.to_bits());
            assert_eq!(x.event, y.event, "replay must be bit-identical");
        }
        // events come in retire/birth pairs at identical times
        let evs = a.events();
        assert_eq!(evs.len() % 2, 0);
        for pair in evs.chunks(2) {
            assert_eq!(pair[0].t.to_bits(), pair[1].t.to_bits());
            assert!(matches!(pair[0].event, WorldEvent::PageRetired { .. }));
            assert!(matches!(pair[1].event, WorldEvent::PageBorn { .. }));
        }
    }

    #[test]
    fn flash_crowd_reverts_exactly() {
        let init = initial(40);
        let mut sc = Scenario::new(init.clone(), 5);
        add_flash_crowd(&mut sc, 10.0, 5.0, 0.25, 8.0, 2.0, 9);
        let surges: Vec<_> =
            sc.events().iter().filter(|e| e.t == 10.0).collect();
        let reverts: Vec<_> =
            sc.events().iter().filter(|e| e.t == 15.0).collect();
        assert_eq!(surges.len(), 10);
        assert_eq!(reverts.len(), 10);
        for r in reverts {
            let WorldEvent::ParamsChanged { page, params } = &r.event else {
                panic!("flash crowd must emit ParamsChanged");
            };
            assert_eq!(*params, init[*page], "revert must restore the original page");
        }
    }

    #[test]
    fn diurnal_drift_oscillates_delta() {
        let init = initial(10);
        let mut sc = Scenario::new(init.clone(), 6);
        add_diurnal_drift(&mut sc, 40.0, 0.5, 4, 1.0, 80.0, 3);
        assert!(!sc.is_static());
        let mut lo = f64::INFINITY;
        let mut hi = f64::NEG_INFINITY;
        for e in sc.events() {
            let WorldEvent::ParamsChanged { page, params } = &e.event else {
                panic!("drift must emit ParamsChanged");
            };
            let ratio = params.delta / init[*page].delta;
            lo = lo.min(ratio);
            hi = hi.max(ratio);
            assert!(params.delta > 0.0);
        }
        assert!(lo < 0.75 && hi > 1.25, "drift never oscillated: [{lo}, {hi}]");
    }

    #[test]
    fn correlated_outages_cover_whole_hosts() {
        let mut sc = Scenario::new(initial(24), 8);
        add_correlated_outages(&mut sc, 4, 6, 3.0, 50.0, 11);
        assert_eq!(sc.events().len(), 6);
        for e in sc.events() {
            let WorldEvent::CisOutage { pages: PageSet::Pages(members), duration } = &e.event
            else {
                panic!("outage generator must emit host page lists");
            };
            assert!(*duration > 0.0);
            assert_eq!(members.len(), 6, "24 pages over 4 hosts = 6 per host");
            let h = members[0] % 4;
            assert!(members.iter().all(|&i| i % 4 == h), "outage must cover one host");
        }
    }
}
