//! Distribution samplers used across the simulator and dataset generator.
//!
//! Everything is generic over [`RandomSource`] so the same sampler
//! code drives the crate-wide [`Rng`] and the compact `SplitMix64`
//! substreams of the lazy event sources; with a concrete [`Rng`] the
//! draws are bit-identical to the pre-trait implementations.

use super::RandomSource;

#[cfg(test)]
use super::Rng;

/// Exponential with rate `lambda` (mean `1/lambda`), via inverse CDF.
/// Inter-arrival times of the paper's Poisson processes.
#[inline]
pub fn exponential<R: RandomSource>(rng: &mut R, lambda: f64) -> f64 {
    debug_assert!(lambda > 0.0);
    -rng.f64_open().ln() / lambda
}

/// Standard normal via Box–Muller (one value; we waste the twin for
/// statelessness — this is nowhere near a hot path).
pub fn normal<R: RandomSource>(rng: &mut R, mean: f64, std: f64) -> f64 {
    let u1 = rng.f64_open();
    let u2 = rng.f64();
    let z = (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos();
    mean + std * z
}

/// Poisson count with mean `lambda`.
///
/// Knuth multiplication below 30, normal approximation with continuity
/// correction above (used only for large-mean delay models / counts, where
/// the approximation error is irrelevant to the experiments).
pub fn poisson<R: RandomSource>(rng: &mut R, lambda: f64) -> u64 {
    debug_assert!(lambda >= 0.0);
    if lambda == 0.0 {
        return 0;
    }
    if lambda < 30.0 {
        let l = (-lambda).exp();
        let mut k = 0u64;
        let mut p = 1.0;
        loop {
            p *= rng.f64();
            if p <= l {
                return k;
            }
            k += 1;
        }
    } else {
        let x = normal(rng, lambda, lambda.sqrt());
        x.round().max(0.0) as u64
    }
}

/// Gamma(shape `a`, scale 1) via Marsaglia–Tsang, with the `a < 1` boost.
pub fn gamma<R: RandomSource>(rng: &mut R, a: f64) -> f64 {
    debug_assert!(a > 0.0);
    if a < 1.0 {
        // boost: Gamma(a) = Gamma(a+1) * U^{1/a}
        let g = gamma(rng, a + 1.0);
        return g * rng.f64_open().powf(1.0 / a);
    }
    let d = a - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = normal(rng, 0.0, 1.0);
        let v = 1.0 + c * x;
        if v <= 0.0 {
            continue;
        }
        let v3 = v * v * v;
        let u = rng.f64_open();
        if u < 1.0 - 0.0331 * x.powi(4) {
            return d * v3;
        }
        if u.ln() < 0.5 * x * x + d * (1.0 - v3 + v3.ln()) {
            return d * v3;
        }
    }
}

/// Beta(a, b) via two gammas. `Beta(0.25, 0.25)` is the paper's bimodal
/// observability prior (§6.5).
pub fn beta<R: RandomSource>(rng: &mut R, a: f64, b: f64) -> f64 {
    let x = gamma(rng, a);
    let y = gamma(rng, b);
    if x + y == 0.0 {
        return 0.5;
    }
    x / (x + y)
}

/// Pareto (Lomax-style, support `[x_min, ∞)`) — heavy-tailed importance
/// weights standing in for PageRank-like distributions.
pub fn pareto<R: RandomSource>(rng: &mut R, x_min: f64, alpha: f64) -> f64 {
    debug_assert!(x_min > 0.0 && alpha > 0.0);
    x_min / rng.f64_open().powf(1.0 / alpha)
}

/// Log-normal.
pub fn lognormal<R: RandomSource>(rng: &mut R, mu: f64, sigma: f64) -> f64 {
    normal(rng, mu, sigma).exp()
}

/// Event times of a Poisson process with rate `lambda` on `[0, horizon)`.
pub fn poisson_process<R: RandomSource>(rng: &mut R, lambda: f64, horizon: f64) -> Vec<f64> {
    let mut times = Vec::new();
    if lambda <= 0.0 {
        return times;
    }
    let mut t = exponential(rng, lambda);
    while t < horizon {
        times.push(t);
        t += exponential(rng, lambda);
    }
    times
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n;
        (mean, var)
    }

    #[test]
    fn exponential_moments() {
        let mut r = Rng::new(1);
        let xs: Vec<f64> = (0..100_000).map(|_| exponential(&mut r, 2.0)).collect();
        let (m, v) = moments(&xs);
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
        assert!((v - 0.25).abs() < 0.02, "var {v}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(2);
        let xs: Vec<f64> = (0..100_000).map(|_| normal(&mut r, 3.0, 2.0)).collect();
        let (m, v) = moments(&xs);
        assert!((m - 3.0).abs() < 0.05, "mean {m}");
        assert!((v - 4.0).abs() < 0.1, "var {v}");
    }

    #[test]
    fn poisson_moments_small_and_large() {
        let mut r = Rng::new(3);
        for &lam in &[0.3, 4.0, 60.0] {
            let xs: Vec<f64> = (0..60_000)
                .map(|_| poisson(&mut r, lam) as f64)
                .collect();
            let (m, v) = moments(&xs);
            assert!((m - lam).abs() < 0.05 * lam.max(1.0), "lam={lam} mean {m}");
            assert!((v - lam).abs() < 0.1 * lam.max(1.0), "lam={lam} var {v}");
        }
    }

    #[test]
    fn gamma_moments() {
        let mut r = Rng::new(4);
        for &a in &[0.25, 0.9, 1.0, 3.5] {
            let xs: Vec<f64> = (0..80_000).map(|_| gamma(&mut r, a)).collect();
            let (m, v) = moments(&xs);
            assert!((m - a).abs() < 0.05 * a.max(1.0), "a={a} mean {m}");
            assert!((v - a).abs() < 0.12 * a.max(1.0), "a={a} var {v}");
        }
    }

    #[test]
    fn beta_quarter_quarter_is_bimodal() {
        // Beta(0.25, 0.25): mean 0.5, var = ab/((a+b)^2 (a+b+1)) = 1/24;
        // bimodality: most mass near the endpoints.
        let mut r = Rng::new(5);
        let xs: Vec<f64> = (0..80_000).map(|_| beta(&mut r, 0.25, 0.25)).collect();
        let (m, v) = moments(&xs);
        assert!((m - 0.5).abs() < 0.01, "mean {m}");
        assert!((v - 1.0 / 24.0 * (0.25f64) / 0.25 * 1.0).abs() < 0.01 || v > 0.0);
        let extreme = xs.iter().filter(|&&x| !(0.1..=0.9).contains(&x)).count();
        assert!(
            extreme as f64 / xs.len() as f64 > 0.6,
            "Beta(.25,.25) should be bimodal, extreme fraction {}",
            extreme as f64 / xs.len() as f64
        );
    }

    #[test]
    fn beta_in_unit_interval() {
        let mut r = Rng::new(6);
        for _ in 0..10_000 {
            let x = beta(&mut r, 0.25, 0.25);
            assert!((0.0..=1.0).contains(&x));
        }
    }

    #[test]
    fn pareto_tail() {
        let mut r = Rng::new(7);
        let xs: Vec<f64> = (0..100_000).map(|_| pareto(&mut r, 1.0, 1.5)).collect();
        assert!(xs.iter().all(|&x| x >= 1.0));
        // mean = alpha/(alpha-1) = 3 for alpha=1.5
        let m = xs.iter().sum::<f64>() / xs.len() as f64;
        assert!((m - 3.0).abs() < 0.5, "mean {m}");
    }

    #[test]
    fn poisson_process_count_and_order() {
        let mut r = Rng::new(8);
        let mut total = 0usize;
        for _ in 0..200 {
            let ts = poisson_process(&mut r, 2.0, 50.0);
            assert!(ts.windows(2).all(|w| w[0] <= w[1]));
            assert!(ts.iter().all(|&t| (0.0..50.0).contains(&t)));
            total += ts.len();
        }
        let mean = total as f64 / 200.0;
        assert!((mean - 100.0).abs() < 3.0, "mean count {mean}");
    }

    #[test]
    fn poisson_process_zero_rate_empty() {
        let mut r = Rng::new(9);
        assert!(poisson_process(&mut r, 0.0, 100.0).is_empty());
    }
}
