//! xoshiro256++ PRNG seeded via SplitMix64 (Blackman & Vigna).

/// Uniform random bits, abstracted over the generator.
///
/// The distribution samplers in [`crate::rngkit`] are generic over this
/// trait so the same code drives both the crate-wide [`Rng`]
/// (xoshiro256++, 32 bytes of state) and the compact [`SplitMix64`]
/// (8 bytes) the lazy event sources keep three-per-page. The provided
/// conversions are byte-for-byte the formulas of [`Rng`]'s inherent
/// methods, so a generic sampler called with a concrete [`Rng`] draws
/// exactly what it drew before the trait existed.
pub trait RandomSource {
    /// Next 64 uniform bits.
    fn next_u64(&mut self) -> u64;

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    #[inline]
    fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` — safe as an argument to `ln`.
    #[inline]
    fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Random boolean with probability `p`.
    #[inline]
    fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }
}

/// SplitMix64: used for seeding, cheap stateless hashing, and as the
/// compact per-substream generator of the lazy event sources.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    /// New generator from a seed.
    pub fn new(seed: u64) -> Self {
        Self { state: seed }
    }

    /// Next 64-bit output.
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

impl RandomSource for SplitMix64 {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        SplitMix64::next_u64(self)
    }
}

impl RandomSource for Rng {
    #[inline]
    fn next_u64(&mut self) -> u64 {
        Rng::next_u64(self)
    }
}

/// xoshiro256++ — the crate-wide RNG. Deterministic, seedable, and
/// *splittable*: [`Rng::split`] derives an independent stream, which the
/// sharded coordinator uses to give every shard/page its own stream.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    /// New generator from a seed (expanded via SplitMix64).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        // avoid the all-zero state
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Self { s }
    }

    /// Derive an independent child stream (hash of current output).
    pub fn split(&mut self, tag: u64) -> Rng {
        let a = self.next_u64();
        let mut sm = SplitMix64::new(a ^ tag.wrapping_mul(0xA24B_AED4_963E_E407));
        let mut s = [0u64; 4];
        for v in s.iter_mut() {
            *v = sm.next_u64();
        }
        Rng { s }
    }

    /// Derive an independent *compact* child stream (same keying as
    /// [`Self::split`], but the child is a [`SplitMix64`] with 8 bytes
    /// of state instead of 32). The lazy event sources keep three of
    /// these per page, so substream state is 24 bytes per page instead
    /// of 96.
    pub fn split64(&mut self, tag: u64) -> SplitMix64 {
        let a = self.next_u64();
        SplitMix64::new(a ^ tag.wrapping_mul(0xA24B_AED4_963E_E407))
    }

    /// Next 64-bit output.
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[0]
            .wrapping_add(s[3])
            .rotate_left(23)
            .wrapping_add(s[0]);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform f64 in `[0, 1)` (53-bit mantissa).
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform f64 in `(0, 1]` — safe as an argument to `ln`.
    #[inline]
    pub fn f64_open(&mut self) -> f64 {
        1.0 - self.f64()
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's method, bias-free for our n).
    #[inline]
    pub fn below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0);
        // 128-bit multiply rejection
        loop {
            let x = self.next_u64();
            let m = (x as u128) * (n as u128);
            let l = m as u64;
            if l >= n.wrapping_neg() % n {
                return (m >> 64) as u64;
            }
        }
    }

    /// Random boolean with probability `p`.
    #[inline]
    pub fn bernoulli(&mut self, p: f64) -> bool {
        self.f64() < p
    }

    /// Fisher-Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Sample `k` distinct indices from `[0, n)` (partial Fisher-Yates).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n);
        let mut idx: Vec<usize> = (0..n).collect();
        for i in 0..k {
            let j = i + self.below((n - i) as u64) as usize;
            idx.swap(i, j);
        }
        idx.truncate(k);
        idx
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_and_seed_sensitive() {
        let mut a = Rng::new(1);
        let mut b = Rng::new(1);
        let mut c = Rng::new(2);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn uniform_moments() {
        let mut r = Rng::new(7);
        let n = 200_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var {var}");
    }

    #[test]
    fn below_is_unbiased_ish() {
        let mut r = Rng::new(3);
        let mut counts = [0u32; 7];
        for _ in 0..70_000 {
            counts[r.below(7) as usize] += 1;
        }
        for &c in &counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "{counts:?}");
        }
    }

    #[test]
    fn split_streams_differ() {
        let mut r = Rng::new(9);
        let mut a = r.split(0);
        let mut b = r.split(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb);
    }

    #[test]
    fn split64_streams_differ_and_are_deterministic() {
        let mut r1 = Rng::new(9);
        let mut r2 = Rng::new(9);
        let mut a = r1.split64(0);
        let mut b = r1.split64(1);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        assert_ne!(va, vb, "sub-keys must decorrelate");
        let mut a2 = r2.split64(0);
        let va2: Vec<u64> = (0..8).map(|_| a2.next_u64()).collect();
        assert_eq!(va, va2, "same parent state + tag must replay");
    }

    #[test]
    fn trait_f64_matches_inherent_f64() {
        // the generic samplers rely on RandomSource::f64 being
        // bit-identical to Rng::f64
        let mut a = Rng::new(33);
        let mut b = Rng::new(33);
        for _ in 0..64 {
            let inherent = a.f64();
            let via_trait = RandomSource::f64(&mut b);
            assert_eq!(inherent.to_bits(), via_trait.to_bits());
        }
    }

    #[test]
    fn splitmix_f64_is_uniform_ish() {
        let mut sm = SplitMix64::new(77);
        let n = 100_000;
        let mut s = 0.0;
        for _ in 0..n {
            let x = RandomSource::f64(&mut sm);
            assert!((0.0..1.0).contains(&x));
            s += x;
        }
        let mean = s / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(11);
        let mut v: Vec<usize> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn sample_indices_distinct() {
        let mut r = Rng::new(13);
        let idx = r.sample_indices(1000, 100);
        let mut s = idx.clone();
        s.sort_unstable();
        s.dedup();
        assert_eq!(s.len(), 100);
    }
}
