//! Deterministic RNG + distribution substrate.
//!
//! The image has no `rand`/`rand_distr`, so this module provides what the
//! simulator and dataset generator need: a fast, seedable, splittable
//! generator ([`Rng`], xoshiro256++) and the samplers the paper's
//! experiments call for — exponential inter-arrival times (Poisson
//! processes), `Beta(0.25, 0.25)` observability parameters, uniform false-
//! positive rates, Poisson counts, and heavy-tailed importance weights.
//!
//! Unit tests validate every sampler against closed-form moments.

mod distributions;
mod xoshiro;

pub use distributions::*;
pub use xoshiro::{RandomSource, Rng, SplitMix64};
