//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the image has no `thiserror`);
//! the XLA conversion only exists when the `pjrt` feature brings the
//! `xla` crate into the build.

/// Errors surfaced by the ncis-crawl library.
#[derive(Debug)]
pub enum Error {
    /// Invalid page / environment parameters.
    InvalidParam(String),
    /// The continuous solver could not bracket or converge.
    Solver(String),
    /// PJRT / artifact problems.
    Runtime(String),
    /// Artifact manifest problems.
    Manifest(String),
    /// Configuration file problems.
    Config(String),
    /// CLI usage problems.
    Usage(String),
    /// Underlying XLA error (stringified; only produced with `pjrt`).
    Xla(String),
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidParam(s) => write!(f, "invalid parameter: {s}"),
            Error::Solver(s) => write!(f, "solver failure: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Manifest(s) => write!(f, "artifact manifest: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Usage(s) => write!(f, "usage: {s}"),
            Error::Xla(s) => write!(f, "xla: {s}"),
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        assert_eq!(Error::Usage("bad flag".into()).to_string(), "usage: bad flag");
        assert!(Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "x"))
            .to_string()
            .starts_with("io: "));
    }

    #[test]
    fn io_errors_convert() {
        fn fails() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "nope"))?;
            Ok(())
        }
        assert!(matches!(fails(), Err(Error::Io(_))));
    }
}
