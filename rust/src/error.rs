//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the image has no `thiserror`);
//! the XLA conversion only exists when the `pjrt` feature brings the
//! `xla` crate into the build.

/// Errors surfaced by the ncis-crawl library.
#[derive(Debug)]
pub enum Error {
    /// Invalid page / environment parameters.
    InvalidParam(String),
    /// The continuous solver could not bracket or converge.
    Solver(String),
    /// PJRT / artifact problems.
    Runtime(String),
    /// Artifact manifest problems.
    Manifest(String),
    /// Configuration file problems.
    Config(String),
    /// CLI usage problems.
    Usage(String),
    /// Underlying XLA error (stringified; only produced with `pjrt`).
    Xla(String),
    /// One or more shard workers of the streaming pipeline panicked.
    /// The surviving shards' work is salvaged instead of aborting the
    /// process: `crawls_per_shard` holds per-shard crawl totals (0 for
    /// the failed shards), `failed` the shard indices with their panic
    /// payloads.
    WorkerFailed {
        /// `(shard index, panic payload)` per failed worker.
        failed: Vec<(usize, String)>,
        /// Salvaged per-shard crawl totals (failed shards report 0).
        crawls_per_shard: Vec<u64>,
    },
    /// I/O error.
    Io(std::io::Error),
}

impl std::fmt::Display for Error {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            Error::InvalidParam(s) => write!(f, "invalid parameter: {s}"),
            Error::Solver(s) => write!(f, "solver failure: {s}"),
            Error::Runtime(s) => write!(f, "runtime: {s}"),
            Error::Manifest(s) => write!(f, "artifact manifest: {s}"),
            Error::Config(s) => write!(f, "config: {s}"),
            Error::Usage(s) => write!(f, "usage: {s}"),
            Error::Xla(s) => write!(f, "xla: {s}"),
            Error::WorkerFailed { failed, .. } => {
                write!(f, "{} shard worker(s) panicked:", failed.len())?;
                for (shard, payload) in failed {
                    write!(f, " [shard {shard}: {payload}]")?;
                }
                Ok(())
            }
            Error::Io(e) => write!(f, "io: {e}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

#[cfg(feature = "pjrt")]
impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_includes_context() {
        assert_eq!(Error::Usage("bad flag".into()).to_string(), "usage: bad flag");
        assert!(Error::Io(std::io::Error::new(std::io::ErrorKind::Other, "x"))
            .to_string()
            .starts_with("io: "));
    }

    #[test]
    fn worker_failed_lists_every_shard_and_keeps_salvage() {
        let e = Error::WorkerFailed {
            failed: vec![(1, "boom".into()), (3, "bust".into())],
            crawls_per_shard: vec![10, 0, 12, 0],
        };
        let msg = e.to_string();
        assert!(msg.contains("2 shard worker(s) panicked"), "{msg}");
        assert!(msg.contains("[shard 1: boom]") && msg.contains("[shard 3: bust]"), "{msg}");
        if let Error::WorkerFailed { crawls_per_shard, .. } = e {
            assert_eq!(crawls_per_shard, vec![10, 0, 12, 0], "sibling work salvaged");
        }
    }

    #[test]
    fn io_errors_convert() {
        fn fails() -> Result<()> {
            Err(std::io::Error::new(std::io::ErrorKind::NotFound, "nope"))?;
            Ok(())
        }
        assert!(matches!(fails(), Err(Error::Io(_))));
    }
}
