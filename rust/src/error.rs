//! Crate-wide error type.

/// Errors surfaced by the ncis-crawl library.
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Invalid page / environment parameters.
    #[error("invalid parameter: {0}")]
    InvalidParam(String),
    /// The continuous solver could not bracket or converge.
    #[error("solver failure: {0}")]
    Solver(String),
    /// PJRT / artifact problems.
    #[error("runtime: {0}")]
    Runtime(String),
    /// Artifact manifest problems.
    #[error("artifact manifest: {0}")]
    Manifest(String),
    /// Configuration file problems.
    #[error("config: {0}")]
    Config(String),
    /// CLI usage problems.
    #[error("usage: {0}")]
    Usage(String),
    /// Underlying XLA error.
    #[error("xla: {0}")]
    Xla(#[from] xla::Error),
    /// I/O error.
    #[error("io: {0}")]
    Io(#[from] std::io::Error),
}

/// Crate-wide result alias.
pub type Result<T> = std::result::Result<T, Error>;
