//! Small shared utilities.

/// Total-ordered `f64` wrapper for heap/sort keys.
///
/// Comparison falls back to `Equal` on NaN, which is safe for every
/// in-tree use: heap keys are event times, wake times and crawl values,
/// all of which are NaN-free by construction (the lazy scheduler
/// `debug_assert`s it; event traces come from finite samplers). Shared
/// by the §5.2 lazy scheduler's wake/hot heaps and the streaming sim
/// engine's k-way merge heap.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct OrdF64(pub f64);

impl Eq for OrdF64 {}

impl PartialOrd for OrdF64 {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for OrdF64 {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap_or(std::cmp::Ordering::Equal)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::cmp::Reverse;
    use std::collections::BinaryHeap;

    #[test]
    fn orders_like_f64() {
        assert!(OrdF64(1.0) < OrdF64(2.0));
        assert!(OrdF64(-1.0) < OrdF64(0.0));
        assert_eq!(OrdF64(3.5), OrdF64(3.5));
        assert!(OrdF64(f64::NEG_INFINITY) < OrdF64(f64::INFINITY));
    }

    #[test]
    fn works_as_min_heap_key() {
        let mut h = BinaryHeap::new();
        for x in [3.0, 1.0, 2.0] {
            h.push(Reverse((OrdF64(x), 0u8)));
        }
        let order: Vec<f64> = std::iter::from_fn(|| h.pop().map(|Reverse((OrdF64(x), _))| x))
            .collect();
        assert_eq!(order, vec![1.0, 2.0, 3.0]);
    }

    #[test]
    fn tuple_tie_break_by_second_field() {
        let mut h = BinaryHeap::new();
        h.push(Reverse((OrdF64(1.0), 2u8)));
        h.push(Reverse((OrdF64(1.0), 1u8)));
        let Reverse((_, k)) = h.pop().unwrap();
        assert_eq!(k, 1);
    }
}
