//! Terminal figure rendering: turn the CSV series under
//! `target/figures/` into ASCII line charts so results are inspectable
//! without leaving the terminal (`ncis-crawl report <figure-csv>`).

use std::path::Path;

use crate::error::{Error, Result};

/// A parsed numeric CSV (header + column-major data).
#[derive(Debug, Clone)]
pub struct Table {
    /// Column names.
    pub columns: Vec<String>,
    /// Column-major values (NaN for unparsable cells).
    pub data: Vec<Vec<f64>>,
}

impl Table {
    /// Parse CSV text.
    pub fn parse(text: &str) -> Result<Table> {
        let mut lines = text.lines().filter(|l| !l.trim().is_empty());
        let header = lines.next().ok_or_else(|| Error::InvalidParam("empty csv".into()))?;
        let columns: Vec<String> = header.split(',').map(|s| s.trim().to_string()).collect();
        let mut data: Vec<Vec<f64>> = vec![Vec::new(); columns.len()];
        for line in lines {
            for (j, cell) in line.split(',').enumerate() {
                if j < data.len() {
                    data[j].push(cell.trim().parse().unwrap_or(f64::NAN));
                }
            }
        }
        Ok(Table { columns, data })
    }

    /// Load from a file.
    pub fn load(path: &Path) -> Result<Table> {
        Self::parse(&std::fs::read_to_string(path)?)
    }

    /// Column index by name.
    pub fn col(&self, name: &str) -> Option<usize> {
        self.columns.iter().position(|c| c == name)
    }
}

/// Render series as an ASCII chart: first column is x, remaining
/// numeric columns are series (up to 6, marked with distinct glyphs).
pub fn render_chart(table: &Table, width: usize, height: usize) -> String {
    const GLYPHS: [char; 6] = ['*', 'o', '+', 'x', '#', '@'];
    if table.data.is_empty() || table.data[0].is_empty() {
        return "(no data)".into();
    }
    let x = &table.data[0];
    let series: Vec<usize> = (1..table.columns.len())
        .filter(|&j| !table.columns[j].ends_with("_se") && !table.columns[j].ends_with("stderr"))
        .take(6)
        .collect();
    let (mut ymin, mut ymax) = (f64::INFINITY, f64::NEG_INFINITY);
    for &j in &series {
        for &v in &table.data[j] {
            if v.is_finite() {
                ymin = ymin.min(v);
                ymax = ymax.max(v);
            }
        }
    }
    if !ymin.is_finite() || ymax <= ymin {
        ymax = ymin + 1.0;
    }
    let (xmin, xmax) = (
        x.iter().cloned().fold(f64::INFINITY, f64::min),
        x.iter().cloned().fold(f64::NEG_INFINITY, f64::max),
    );
    let xspan = (xmax - xmin).max(1e-12);
    let mut grid = vec![vec![' '; width]; height];
    for (si, &j) in series.iter().enumerate() {
        for (k, &xv) in x.iter().enumerate() {
            let yv = table.data[j].get(k).copied().unwrap_or(f64::NAN);
            if !yv.is_finite() || !xv.is_finite() {
                continue;
            }
            let cx = (((xv - xmin) / xspan) * (width - 1) as f64).round() as usize;
            let cy = (((yv - ymin) / (ymax - ymin)) * (height - 1) as f64).round() as usize;
            let row = height - 1 - cy.min(height - 1);
            grid[row][cx.min(width - 1)] = GLYPHS[si % GLYPHS.len()];
        }
    }
    let mut out = String::new();
    out.push_str(&format!("{ymax:>10.4} ┤\n"));
    for row in &grid {
        out.push_str("           │");
        out.extend(row.iter());
        out.push('\n');
    }
    out.push_str(&format!("{ymin:>10.4} └{}\n", "─".repeat(width)));
    out.push_str(&format!("            {xmin:<12.4}{:>w$.4}\n", xmax, w = width.saturating_sub(12)));
    for (si, &j) in series.iter().enumerate() {
        out.push_str(&format!("  {} {}\n", GLYPHS[si % GLYPHS.len()], table.columns[j]));
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const CSV: &str = "m,baseline,GREEDY,GREEDY_se\n100,0.8,0.79,0.01\n200,0.7,0.71,0.01\n300,0.6,0.62,0.02\n";

    #[test]
    fn parse_table() {
        let t = Table::parse(CSV).unwrap();
        assert_eq!(t.columns.len(), 4);
        assert_eq!(t.data[0], vec![100.0, 200.0, 300.0]);
        assert_eq!(t.col("GREEDY"), Some(2));
        assert_eq!(t.col("nope"), None);
    }

    #[test]
    fn render_has_series_and_legend() {
        let t = Table::parse(CSV).unwrap();
        let chart = render_chart(&t, 40, 10);
        assert!(chart.contains('*'));
        assert!(chart.contains("baseline"));
        assert!(chart.contains("GREEDY"));
        // stderr columns are excluded from the plot legend
        assert!(!chart.contains("GREEDY_se"));
    }

    #[test]
    fn empty_and_degenerate() {
        assert!(Table::parse("").is_err());
        let t = Table::parse("x,y\n").unwrap();
        assert_eq!(render_chart(&t, 20, 5), "(no data)");
        // constant series must not divide by zero
        let t = Table::parse("x,y\n1,5\n2,5\n").unwrap();
        let _ = render_chart(&t, 20, 5);
    }
}
