//! Fault-aware merge engine: the streaming k-way merge of
//! [`crate::sim::engine::simulate_source_with`] with a crawl step that
//! can *fail*.
//!
//! Each tick buys at most one fetch **attempt**. A due retry (scheduled
//! by the [`RetryPolicy`] after an earlier failure) takes precedence
//! over the scheduler's pick, so retries consume real bandwidth ticks
//! and the constant-total-rate invariant survives: `successes +
//! failures + forfeited + idle == ticks`, always. Failed attempts waste
//! their tick — no freshness reset, no crawl count — and are surfaced
//! to the scheduler via
//! [`crate::sched::CrawlScheduler::on_crawl_failed`]. A page whose
//! consecutive-failure budget is spent (or that is permanently
//! [`CrawlOutcome::Gone`]) is **quarantined**: the scheduler is told
//! via `on_page_removed`, its pending CIS stop being delivered, and it
//! is never fetched again; a scheduler that still picks it forfeits the
//! tick (counted, not crashed).
//!
//! With an inert [`FaultModel`] the crawl step collapses to exactly the
//! fault-free transitions — zero RNG draws, an empty retry heap — so
//! the zero-fault run is bit-identical to the plain engine (pinned by
//! `tests/fault_injection.rs` for both materialized and streamed
//! sources).

use std::cmp::Reverse;
use std::collections::BinaryHeap;

use crate::fault::{CrawlOutcome, FaultModel, FaultStats, RetryPolicy};
use crate::sched::CrawlScheduler;
use crate::sim::engine::{KIND_CHANGE, KIND_REQUEST};
use crate::sim::events::EventTraces;
use crate::sim::source::{EventSource, ReplaySource, StreamedSource};
use crate::sim::{SimConfig, SimResult, SimWorkspace};
use crate::trace::{self, SpanKind, TraceEvent};
use crate::util::OrdF64;

/// Outcome of one faulty repetition: the usual freshness accounting
/// plus the degraded-mode ledger.
#[derive(Debug, Clone)]
pub struct FaultSimResult {
    /// Freshness/bandwidth accounting (identical shape to the fault-free
    /// engine; under an inert model, bit-identical content too).
    pub sim: SimResult,
    /// Degraded-mode accounting: attempts, failures by kind, retries,
    /// quarantines, forfeited/idle ticks, per-host retry histogram.
    pub faults: FaultStats,
}

/// Run one faulty repetition over pre-materialized traces with a
/// throwaway workspace. Repetition loops should allocate one
/// [`SimWorkspace`] and call [`simulate_faulty_with`].
pub fn simulate_faulty(
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    model: &mut FaultModel,
    retry: RetryPolicy,
) -> FaultSimResult {
    let mut ws = SimWorkspace::new();
    simulate_faulty_with(&mut ws, traces, cfg, scheduler, model, retry)
}

/// Faulty analogue of [`crate::sim::simulate_with`]: replay
/// pre-materialized traces (borrowing the workspace's cursor pool)
/// through the fault-aware merge loop.
pub fn simulate_faulty_with(
    ws: &mut SimWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    model: &mut FaultModel,
    retry: RetryPolicy,
) -> FaultSimResult {
    let mut source =
        ReplaySource::with_cursors(&traces.pages, std::mem::take(&mut ws.cursor_pool));
    let res = simulate_faulty_source_with(ws, &mut source, cfg, scheduler, model, retry);
    ws.cursor_pool = source.into_cursors();
    res
}

/// [`simulate_faulty_with`] with an optional trace sink: the retry /
/// quarantine / forfeit transitions land in the flight recorder as
/// they happen. `tr = None` is branch-for-branch the untraced engine
/// (pinned by `tests/trace_parity.rs`).
pub fn simulate_faulty_traced_with(
    ws: &mut SimWorkspace,
    traces: &EventTraces,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    model: &mut FaultModel,
    retry: RetryPolicy,
    tr: Option<&crate::trace::TraceHandle>,
) -> FaultSimResult {
    let mut source =
        ReplaySource::with_cursors(&traces.pages, std::mem::take(&mut ws.cursor_pool));
    let res = simulate_faulty_source_traced_with(ws, &mut source, cfg, scheduler, model, retry, tr);
    ws.cursor_pool = source.into_cursors();
    res
}

/// Faulty analogue of [`crate::sim::simulate_streamed_with`]: drive a
/// lazy [`StreamedSource`] (taken by value — single pass) through the
/// fault-aware merge loop.
pub fn simulate_faulty_streamed_with(
    ws: &mut SimWorkspace,
    mut source: StreamedSource,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    model: &mut FaultModel,
    retry: RetryPolicy,
) -> FaultSimResult {
    simulate_faulty_source_with(ws, &mut source, cfg, scheduler, model, retry)
}

/// The fault-aware merge engine, generic over the event source.
///
/// Identical event application to the fault-free engine (same `(time,
/// kind, page)` total order, same discard window, same rolling ring);
/// only the per-tick crawl step differs — see the module docs for the
/// attempt/retry/quarantine semantics. The caller is expected to pass a
/// validated `retry` policy ([`RetryPolicy::validate`]).
pub fn simulate_faulty_source_with<S: EventSource>(
    ws: &mut SimWorkspace,
    source: &mut S,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    model: &mut FaultModel,
    retry: RetryPolicy,
) -> FaultSimResult {
    simulate_faulty_source_traced_with(ws, source, cfg, scheduler, model, retry, None)
}

/// [`simulate_faulty_source_with`] with an optional trace sink — the
/// generic traced core every other faulty entry point funnels into.
pub fn simulate_faulty_source_traced_with<S: EventSource>(
    ws: &mut SimWorkspace,
    source: &mut S,
    cfg: &SimConfig,
    scheduler: &mut dyn CrawlScheduler,
    model: &mut FaultModel,
    retry: RetryPolicy,
    tr: Option<&crate::trace::TraceHandle>,
) -> FaultSimResult {
    let m = source.len();
    ws.reset(m);
    model.reset(m);
    scheduler.on_start(m);
    for i in 0..m {
        if let Some((t, k)) = source.first(i) {
            ws.set_frontier(i, Some((t, k)));
            ws.heap.push(Reverse((OrdF64(t), k, i as u32)));
        }
    }

    let mut stats = FaultStats::new(model.hosts());
    // retry calendar: min-heap of (due_time, page) with lazy deletion —
    // an entry is live iff `in_retry[page]` and its due time bit-matches
    // `retry_at[page]` (a newer retry or a success strands old entries)
    let mut retry_heap: BinaryHeap<Reverse<(OrdF64, u32)>> = BinaryHeap::new();
    let mut in_retry = vec![false; m];
    let mut retry_at = vec![0.0f64; m];
    let mut quarantined = vec![false; m];
    let mut consec_failures = vec![0u32; m];

    let mut fresh_hits = 0u64;
    let mut requests = 0u64;
    let mut ticks = 0u64;
    let mut ev_count = 0u64; // events applied (merge pops)
    let mut live_count = m; // pages not yet quarantined
    let mut timeline = Vec::new();
    let window = cfg.timeline_window.unwrap_or(0);
    let mut ring_pos = 0usize;
    let mut ring_fresh = 0usize;

    let segs = cfg.bandwidth.segments();
    let mut seg = 0usize; // monotone segment cursor (no rescan per tick)
    let mut t = 0.0f64;
    loop {
        while seg + 1 < segs.len() && segs[seg + 1].0 <= t {
            seg += 1;
        }
        let r = segs[seg].1;
        let next_tick = t + 1.0 / r;
        if next_tick > cfg.horizon {
            break;
        }
        // apply events up to (and including) the tick time
        let ev_t0 = trace::span_clock(tr);
        while let Some(&Reverse((OrdF64(et), kind, page))) = ws.heap.peek() {
            if et > next_tick {
                break;
            }
            ws.heap.pop();
            ev_count += 1;
            let i = page as usize;
            // one live heap entry per page: the popped entry IS the
            // page's frontier
            debug_assert_eq!(ws.frontier_time[i].to_bits(), et.to_bits());
            debug_assert_eq!(ws.frontier_kind[i], kind);
            match kind {
                KIND_CHANGE => {
                    ws.changed[i] = true;
                }
                KIND_REQUEST => {
                    requests += 1;
                    let fresh = !ws.changed[i];
                    if fresh {
                        fresh_hits += 1;
                    }
                    if window > 0 {
                        if ws.ring.len() < window {
                            ws.ring.push(fresh);
                            if fresh {
                                ring_fresh += 1;
                            }
                        } else {
                            if ws.ring[ring_pos] {
                                ring_fresh -= 1;
                            }
                            ws.ring[ring_pos] = fresh;
                            if fresh {
                                ring_fresh += 1;
                            }
                            ring_pos = (ring_pos + 1) % window;
                        }
                    }
                }
                _ => {
                    // KIND_CIS — quarantined pages were removed from
                    // the scheduler's world; stop delivering for them
                    let keep = !quarantined[i]
                        && match cfg.cis_discard_window {
                            Some(w) => et - ws.last_crawl[i] >= w,
                            None => true,
                        };
                    if keep {
                        scheduler.on_cis(i, et);
                        trace::emit(tr, || TraceEvent::Cis { t: et, page });
                    }
                }
            }
            let next = source.advance(i, kind);
            ws.set_frontier(i, next);
            if let Some((nt, nk)) = next {
                ws.heap.push(Reverse((OrdF64(nt), nk, page)));
            }
        }
        trace::span_observe(tr, SpanKind::Events, ev_t0);
        // fetch attempt at the tick: a due retry outranks the scheduler
        t = next_tick;
        ticks += 1;
        let retry_t0 = trace::span_clock(tr);
        let mut is_retry = false;
        let mut target: Option<usize> = None;
        while let Some(&Reverse((OrdF64(due), page))) = retry_heap.peek() {
            if due > t {
                break;
            }
            retry_heap.pop();
            let i = page as usize;
            // lazy deletion: stale entries (superseded due time, page
            // since quarantined or successfully fetched) are skipped
            if !in_retry[i] || quarantined[i] || retry_at[i].to_bits() != due.to_bits() {
                continue;
            }
            in_retry[i] = false;
            is_retry = true;
            target = Some(i);
            break;
        }
        trace::span_observe(tr, SpanKind::Retry, retry_t0);
        if target.is_none() {
            let sel_t0 = trace::span_clock(tr);
            target = scheduler.select(t);
            trace::span_observe(tr, SpanKind::Select, sel_t0);
        }
        match target {
            None => {
                stats.idle_ticks += 1;
                trace::emit(tr, || TraceEvent::Idle { t });
            }
            Some(i) if quarantined[i] => {
                // the scheduler re-picked a removed page: the tick is
                // forfeited (counted, not crashed) — degraded mode
                debug_assert!(!is_retry);
                stats.forfeited_ticks += 1;
                trace::emit(tr, || TraceEvent::Forfeit { t, page: i as u32 });
            }
            Some(i) => {
                debug_assert!(i < m);
                stats.attempts += 1;
                if is_retry {
                    stats.retries += 1;
                    stats.retries_per_host[model.host_of(i)] += 1;
                }
                match model.outcome(i, t) {
                    CrawlOutcome::Success => {
                        stats.successes += 1;
                        consec_failures[i] = 0;
                        in_retry[i] = false; // cancel any pending retry
                        let was_changed = ws.changed[i];
                        scheduler.on_fetch_observed(i, t, was_changed);
                        ws.changed[i] = false;
                        ws.last_crawl[i] = t;
                        ws.crawl_counts[i] += 1;
                        scheduler.on_crawl(i, t);
                        trace::emit(tr, || TraceEvent::Crawl {
                            t,
                            page: i as u32,
                            changed: was_changed,
                        });
                    }
                    outcome => {
                        // failed attempt: the tick is spent, freshness
                        // state untouched
                        match outcome {
                            CrawlOutcome::TransientError => stats.transient_errors += 1,
                            CrawlOutcome::Timeout => stats.timeouts += 1,
                            CrawlOutcome::Gone => stats.gone += 1,
                            CrawlOutcome::Success => unreachable!(),
                        }
                        scheduler.on_crawl_failed(i, t, outcome);
                        trace::emit(tr, || TraceEvent::CrawlFailed {
                            t,
                            page: i as u32,
                            outcome: outcome as u8,
                        });
                        let quarantine = if outcome == CrawlOutcome::Gone {
                            true // permanently dead: never retry
                        } else {
                            consec_failures[i] += 1;
                            match retry.next_delay(consec_failures[i], model.jitter_stream(i)) {
                                Some(d) => {
                                    in_retry[i] = true;
                                    retry_at[i] = t + d;
                                    retry_heap.push(Reverse((OrdF64(t + d), i as u32)));
                                    trace::emit(tr, || TraceEvent::Retry {
                                        t,
                                        page: i as u32,
                                        due: t + d,
                                    });
                                    false
                                }
                                None => true, // attempt budget spent
                            }
                        };
                        if quarantine {
                            quarantined[i] = true;
                            in_retry[i] = false;
                            stats.quarantined += 1;
                            live_count -= 1;
                            scheduler.on_page_removed(i, t);
                            trace::emit(tr, || TraceEvent::Quarantine { t, page: i as u32 });
                        }
                    }
                }
            }
        }
        trace::progress(tr, t, cfg.horizon, ev_count, live_count);
        if window > 0 && !ws.ring.is_empty() {
            timeline.push((t, ring_fresh as f64 / ws.ring.len() as f64));
        }
    }
    // drain remaining request/change events after the final tick
    while let Some(Reverse((OrdF64(_), kind, page))) = ws.heap.pop() {
        let i = page as usize;
        match kind {
            KIND_CHANGE => {
                ws.changed[i] = true;
            }
            KIND_REQUEST => {
                requests += 1;
                if !ws.changed[i] {
                    fresh_hits += 1;
                }
            }
            _ => {}
        }
        let next = source.advance(i, kind);
        ws.set_frontier(i, next);
        if let Some((nt, nk)) = next {
            ws.heap.push(Reverse((OrdF64(nt), nk, page)));
        }
    }

    // invariant checks (debug builds): on violation the flight
    // recorder's last events are dumped to stderr before the panic, so
    // the decision history leading up to the corruption is preserved
    trace::debug_check(
        stats.successes + stats.failures() + stats.forfeited_ticks + stats.idle_ticks == ticks,
        tr,
        "bandwidth conservation: every tick is a success, a failure, a forfeit or idle",
    );
    if cfg!(debug_assertions) {
        let q = quarantined.iter().filter(|&&x| x).count();
        trace::debug_check(
            stats.quarantined == q as u64 && live_count == m - q,
            tr,
            "quarantine arithmetic: counter, flag population and live count must agree",
        );
    }

    FaultSimResult {
        sim: SimResult {
            accuracy: if requests > 0 { fresh_hits as f64 / requests as f64 } else { f64::NAN },
            requests,
            fresh_hits,
            crawl_counts: ws.crawl_counts.clone(),
            ticks,
            timeline,
        },
        faults: stats,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::fault::{FaultConfig, HostOutage};
    use crate::params::PageParams;
    use crate::rngkit::Rng;
    use crate::sched::PageTracker;
    use crate::sim::events::{generate_traces, CisDelay};
    use crate::sim::simulate;

    /// Deterministic state-dependent scheduler (same shape as the
    /// engine parity tests) that also records failure notifications.
    struct StateScore {
        tracker: PageTracker,
        removed: Vec<usize>,
        failed: Vec<(usize, CrawlOutcome)>,
    }
    impl StateScore {
        fn new() -> Self {
            Self { tracker: PageTracker::default(), removed: vec![], failed: vec![] }
        }
    }
    impl CrawlScheduler for StateScore {
        fn on_start(&mut self, m: usize) {
            self.tracker.reset(m);
            self.removed.clear();
            self.failed.clear();
        }
        fn on_cis(&mut self, page: usize, _t: f64) {
            self.tracker.on_cis(page);
        }
        fn on_crawl(&mut self, page: usize, t: f64) {
            self.tracker.on_crawl(page, t);
        }
        fn on_crawl_failed(&mut self, page: usize, _t: f64, outcome: CrawlOutcome) {
            self.failed.push((page, outcome));
        }
        fn on_page_removed(&mut self, page: usize, _t: f64) {
            self.removed.push(page);
        }
        fn select(&mut self, t: f64) -> Option<usize> {
            let mut best = f64::NEG_INFINITY;
            let mut arg = None;
            for i in 0..self.tracker.len() {
                if self.removed.contains(&i) {
                    continue;
                }
                let v = self.tracker.tau_elap(i, t) + 3.7 * self.tracker.n_cis(i) as f64;
                if v > best {
                    best = v;
                    arg = Some(i);
                }
            }
            arg
        }
    }

    fn random_world(seed: u64, m: usize, horizon: f64) -> (Vec<PageParams>, EventTraces) {
        let mut rng = Rng::new(seed);
        let pages: Vec<PageParams> = (0..m)
            .map(|_| PageParams {
                delta: rng.range(0.05, 1.5),
                mu: rng.range(0.05, 1.5),
                lam: rng.f64(),
                nu: rng.range(0.0, 0.8),
            })
            .collect();
        let mut trng = Rng::new(seed ^ 0xDEAD);
        let traces = generate_traces(&pages, horizon, CisDelay::None, &mut trng);
        (pages, traces)
    }

    #[test]
    fn inert_model_is_bit_identical_to_plain_engine() {
        let (_, tr) = random_world(11, 20, 30.0);
        let mut cfg = SimConfig::new(4.0, 30.0).expect("valid config");
        cfg.timeline_window = Some(8);
        let plain = simulate(&tr, &cfg, &mut StateScore::new());
        let mut model = FaultModel::inert();
        let faulty =
            simulate_faulty(&tr, &cfg, &mut StateScore::new(), &mut model, RetryPolicy::default());
        assert_eq!(plain.accuracy.to_bits(), faulty.sim.accuracy.to_bits());
        assert_eq!(plain.requests, faulty.sim.requests);
        assert_eq!(plain.fresh_hits, faulty.sim.fresh_hits);
        assert_eq!(plain.crawl_counts, faulty.sim.crawl_counts);
        assert_eq!(plain.ticks, faulty.sim.ticks);
        assert_eq!(faulty.faults.successes, faulty.sim.ticks, "every tick fetched");
        assert_eq!(faulty.faults.failures(), 0);
        assert_eq!(faulty.faults.wasted_fraction(), 0.0);
    }

    #[test]
    fn bandwidth_conservation_under_heavy_faults() {
        let (_, tr) = random_world(12, 16, 40.0);
        let cfg = SimConfig::new(5.0, 40.0).expect("valid config");
        let mut fc = FaultConfig {
            transient_prob: 0.35,
            timeout_prob: 0.1,
            gone_prob: 0.1,
            hosts: 4,
            seed: 5,
            ..FaultConfig::none()
        };
        fc.add_correlated_outages(6, 4.0, 40.0, 6);
        let mut model = FaultModel::new(fc).expect("valid config");
        let mut sched = StateScore::new();
        let res = simulate_faulty(&tr, &cfg, &mut sched, &mut model, RetryPolicy::default());
        let f = &res.faults;
        assert_eq!(sched.failed.len() as u64, f.failures(), "every failure is surfaced");
        assert_eq!(
            f.successes + f.failures() + f.forfeited_ticks + f.idle_ticks,
            res.sim.ticks,
            "one tick buys at most one attempt"
        );
        assert!(f.failures() > 0, "this config must actually fail sometimes");
        assert_eq!(f.attempts, f.successes + f.failures());
        assert!(f.wasted_fraction() > 0.0 && f.wasted_fraction() < 1.0);
        // crawl_counts only count successes
        assert_eq!(res.sim.crawl_counts.iter().map(|&c| c as u64).sum::<u64>(), f.successes);
    }

    #[test]
    fn gone_pages_are_quarantined_and_notified() {
        let (_, tr) = random_world(13, 10, 30.0);
        let cfg = SimConfig::new(3.0, 30.0).expect("valid config");
        let fc = FaultConfig { gone_prob: 0.4, seed: 21, ..FaultConfig::none() };
        let mut model = FaultModel::new(fc).expect("valid config");
        let mut sched = StateScore::new();
        let res = simulate_faulty(&tr, &cfg, &mut sched, &mut model, RetryPolicy::default());
        assert!(res.faults.gone > 0, "some page must be dead under gone_prob=0.4");
        assert_eq!(res.faults.quarantined as usize, sched.removed.len());
        // a dead page is quarantined on first touch: exactly one Gone
        // attempt per removed page
        assert_eq!(res.faults.gone as usize, sched.removed.len());
        assert_eq!(res.faults.retries, 0, "Gone is never retried");
    }

    #[test]
    fn transient_failures_retry_and_eventually_quarantine() {
        // certain failure: every attempt is transient, so every page
        // burns its attempt budget and lands in quarantine. A
        // pick-each-page-once scheduler makes every attempt after the
        // first come from the retry path, so the retry arithmetic is
        // exact.
        struct PickOnce {
            m: usize,
            next: usize,
        }
        impl CrawlScheduler for PickOnce {
            fn on_start(&mut self, m: usize) {
                self.m = m;
                self.next = 0;
            }
            fn select(&mut self, _t: f64) -> Option<usize> {
                if self.next < self.m {
                    self.next += 1;
                    Some(self.next - 1)
                } else {
                    None
                }
            }
        }
        let (_, tr) = random_world(14, 4, 60.0);
        let cfg = SimConfig::new(2.0, 60.0).expect("valid config");
        let fc = FaultConfig { transient_prob: 1.0, seed: 3, ..FaultConfig::none() };
        let mut model = FaultModel::new(fc).expect("valid config");
        let retry =
            RetryPolicy::ExponentialBackoff { base: 1.0, factor: 2.0, cap: 8.0, max_attempts: 3 };
        let res = simulate_faulty(&tr, &cfg, &mut PickOnce { m: 0, next: 0 }, &mut model, retry);
        assert_eq!(res.faults.successes, 0);
        assert_eq!(res.faults.quarantined, 4, "all pages quarantined");
        // 3 attempts per page (1 scheduler pick + 2 backoff retries),
        // all transient
        assert_eq!(res.faults.transient_errors, 12);
        assert_eq!(res.faults.retries, 8, "2 retries per page");
        assert_eq!(res.faults.attempts, 12);
        // once everything is quarantined, the remaining ticks idle
        assert!(res.faults.idle_ticks > 0);
    }

    #[test]
    fn immediate_retry_consumes_the_very_next_tick() {
        // one page, fails exactly once then succeeds: with Immediate
        // retry the next tick must be the retry attempt
        struct OneShot(bool);
        impl CrawlScheduler for OneShot {
            fn select(&mut self, _t: f64) -> Option<usize> {
                if self.0 {
                    None // after the first pick, only the retry path fetches
                } else {
                    self.0 = true;
                    Some(0)
                }
            }
        }
        let (_, tr) = random_world(15, 1, 10.0);
        let cfg = SimConfig::new(1.0, 10.0).expect("valid config");
        // first coin flip fails w.p. 1 — but only once: use outage window
        // covering only the first tick (t=1) so the retry at t=2 succeeds
        let fc = FaultConfig {
            hosts: 1,
            outages: vec![HostOutage { host: 0, start: 0.0, end: 1.5 }],
            ..FaultConfig::none()
        };
        let mut model = FaultModel::new(fc).expect("valid config");
        let retry = RetryPolicy::Immediate { max_attempts: 5 };
        let res = simulate_faulty(&tr, &cfg, &mut OneShot(false), &mut model, retry);
        assert_eq!(res.faults.timeouts, 1, "tick 1 times out in the outage window");
        assert_eq!(res.faults.retries, 1, "tick 2 is the immediate retry");
        assert_eq!(res.faults.successes, 1, "the retry lands after the window");
        assert_eq!(res.sim.crawl_counts[0], 1);
        assert_eq!(res.faults.idle_ticks, res.sim.ticks - 2);
    }

    #[test]
    fn faulty_replay_is_deterministic() {
        let (_, tr) = random_world(16, 12, 25.0);
        let cfg = SimConfig::new(4.0, 25.0).expect("valid config");
        let fc = FaultConfig {
            transient_prob: 0.25,
            timeout_prob: 0.1,
            gone_prob: 0.05,
            hosts: 3,
            seed: 77,
            ..FaultConfig::none()
        };
        let run = || {
            let mut model = FaultModel::new(fc.clone()).expect("valid config");
            simulate_faulty(&tr, &cfg, &mut StateScore::new(), &mut model, RetryPolicy::default())
        };
        let a = run();
        let b = run();
        assert_eq!(a.sim.accuracy.to_bits(), b.sim.accuracy.to_bits());
        assert_eq!(a.sim.crawl_counts, b.sim.crawl_counts);
        assert_eq!(a.faults, b.faults);
    }
}
