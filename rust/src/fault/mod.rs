//! Fault injection: fetch failures, retry/backoff, quarantine.
//!
//! Every simulated crawl used to succeed instantly — none of the
//! paper's deployment claims (constant total crawl rate, fair freshness
//! under noisy signals) were exercised against the failure modes a real
//! crawler faces: fetches that error or time out, hosts that go dark
//! for minutes at a time, pages that are permanently gone, and retries
//! that silently eat the bandwidth budget. This module provides the
//! failure model and the retry semantics; [`engine`] threads them
//! through the streaming merge engine.
//!
//! - [`FaultModel`] — a deterministic, seedable source of
//!   [`CrawlOutcome`]s: per-page transient-error and timeout
//!   probabilities drawn from per-page RNG substreams (same
//!   `split64` keying discipline as [`crate::sim::source`]),
//!   permanent-dead pages, and correlated host-level outage windows
//!   (round-robin hosts via the shared
//!   [`crate::coordinator::hosts::host_of`] convention, same as
//!   [`crate::scenario::generators::add_correlated_outages`]).
//! - [`RetryPolicy`] — what happens after a failed fetch: immediate
//!   re-queue or exponential backoff with deterministic jitter from the
//!   page's fault substream; after `max_attempts` consecutive failures
//!   the page is **quarantined** (never fetched again, surfaced to the
//!   scheduler via `on_page_removed`).
//! - [`OutageAwareScheduler`] — a politeness-style decorator that
//!   reroutes picks away from hosts inside a known outage window using
//!   the existing `on_veto` machinery, so bandwidth is spent on hosts
//!   that can actually answer.
//! - [`FaultStats`] — degraded-mode accounting: wasted-bandwidth
//!   fraction, per-outcome counts, per-host retry histogram.
//!
//! The **zero-fault config is free**: [`FaultModel::is_inert`] gates
//! every draw, so [`engine::simulate_faulty_source_with`] with
//! [`FaultConfig::none`] performs exactly the state transitions of
//! [`crate::sim::engine::simulate_source_with`] and is pinned
//! bit-identical to it (`tests/fault_injection.rs`).

pub mod engine;

pub use engine::{
    simulate_faulty, simulate_faulty_source_traced_with, simulate_faulty_streamed_with,
    simulate_faulty_traced_with, simulate_faulty_with, FaultSimResult,
};

use crate::coordinator::hosts::host_of;
use crate::error::Error;
use crate::rngkit::{self, RandomSource, Rng, SplitMix64};
use crate::sched::CrawlScheduler;

/// Outcome of one crawl attempt.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CrawlOutcome {
    /// The fetch succeeded: freshness state resets as usual.
    Success,
    /// A transient fetch error (5xx, connection reset): worth retrying.
    TransientError,
    /// The fetch timed out (slow host or host inside an outage window):
    /// worth retrying.
    Timeout,
    /// The page is permanently gone (hard 404/410): never retry.
    Gone,
}

/// A correlated host-level outage: every fetch against `host` during
/// `[start, end)` times out.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct HostOutage {
    /// Host id (`page % hosts` round-robin convention).
    pub host: usize,
    /// Window start (inclusive).
    pub start: f64,
    /// Window end (exclusive).
    pub end: f64,
}

impl HostOutage {
    /// Is `host` dark at time `t` under this window?
    #[inline]
    pub fn covers(&self, host: usize, t: f64) -> bool {
        self.host == host && t >= self.start && t < self.end
    }
}

/// Deterministic, seedable failure-model configuration.
///
/// All probabilities are per crawl *attempt*. Validated by
/// [`FaultModel::new`]; [`FaultConfig::none`] is the canonical
/// zero-fault config, pinned bit-identical to the fault-free engine.
#[derive(Debug, Clone)]
pub struct FaultConfig {
    /// Probability a fetch fails with [`CrawlOutcome::TransientError`].
    pub transient_prob: f64,
    /// Probability a fetch fails with [`CrawlOutcome::Timeout`]
    /// (evaluated after the transient coin).
    pub timeout_prob: f64,
    /// Probability a page is permanently dead (drawn once per page per
    /// run from its fault substream; every fetch of a dead page returns
    /// [`CrawlOutcome::Gone`]).
    pub gone_prob: f64,
    /// Number of hosts for outage correlation (`page % hosts`).
    pub hosts: usize,
    /// Host-level outage windows (fetches inside one time out).
    pub outages: Vec<HostOutage>,
    /// Master seed of the per-page fault substreams.
    pub seed: u64,
}

impl FaultConfig {
    /// The zero-fault configuration: every crawl succeeds, no RNG draw
    /// is ever made, and the fault engine is bit-identical to the
    /// fault-free one.
    pub fn none() -> Self {
        Self {
            transient_prob: 0.0,
            timeout_prob: 0.0,
            gone_prob: 0.0,
            hosts: 1,
            outages: Vec::new(),
            seed: 0,
        }
    }

    /// Validate probabilities, host count and outage windows.
    pub fn validate(&self) -> crate::Result<()> {
        for (name, p) in [
            ("transient_prob", self.transient_prob),
            ("timeout_prob", self.timeout_prob),
            ("gone_prob", self.gone_prob),
        ] {
            if !(0.0..=1.0).contains(&p) || p.is_nan() {
                return Err(Error::InvalidParam(format!(
                    "fault {name} must be in [0, 1], got {p}"
                )));
            }
        }
        if self.hosts == 0 {
            return Err(Error::InvalidParam("fault model needs at least one host".into()));
        }
        for (k, o) in self.outages.iter().enumerate() {
            if o.host >= self.hosts {
                return Err(Error::InvalidParam(format!(
                    "outage {k}: host {} out of range (hosts = {})",
                    o.host, self.hosts
                )));
            }
            if !o.start.is_finite() || !o.end.is_finite() || o.start < 0.0 || o.end <= o.start {
                return Err(Error::InvalidParam(format!(
                    "outage {k}: window [{}, {}) must be finite, non-negative and non-empty",
                    o.start, o.end
                )));
            }
        }
        Ok(())
    }

    /// No fault source is active: no transient/timeout/dead draws and
    /// no outage windows.
    pub fn is_inert(&self) -> bool {
        self.transient_prob == 0.0
            && self.timeout_prob == 0.0
            && self.gone_prob == 0.0
            && self.outages.is_empty()
    }

    /// Append `n_outages` correlated host-level outage windows, the
    /// same shape as
    /// [`crate::scenario::generators::add_correlated_outages`]: uniform
    /// start over the horizon, exponential duration with the given
    /// mean, hosts hit round-robin. Deterministic in `seed`.
    pub fn add_correlated_outages(
        &mut self,
        n_outages: usize,
        mean_duration: f64,
        horizon: f64,
        seed: u64,
    ) {
        assert!(
            mean_duration > 0.0 && mean_duration.is_finite(),
            "mean outage duration must be positive and finite, got {mean_duration}"
        );
        let mut rng = Rng::new(seed);
        for i in 0..n_outages {
            let start = rng.range(0.0, horizon);
            let duration = rngkit::exponential(&mut rng, 1.0 / mean_duration);
            self.outages.push(HostOutage {
                host: host_of(i, self.hosts),
                start,
                end: (start + duration).min(horizon),
            });
        }
    }
}

impl Default for FaultConfig {
    fn default() -> Self {
        Self::none()
    }
}

/// What to do after a failed fetch.
///
/// Retries consume real bandwidth ticks — the engine never fetches
/// twice in one tick, so the constant-total-rate invariant survives
/// every policy here.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum RetryPolicy {
    /// Re-queue the page for the next tick, up to `max_attempts`
    /// consecutive failures, then quarantine.
    Immediate {
        /// Consecutive failures tolerated before quarantine.
        max_attempts: u32,
    },
    /// Exponential backoff: after the `k`-th consecutive failure wait
    /// `min(base · factor^(k-1), cap)`, jittered by a factor in
    /// `[0.5, 1.5)` drawn deterministically from the page's fault
    /// substream; after `max_attempts` failures, quarantine.
    ExponentialBackoff {
        /// Delay after the first failure.
        base: f64,
        /// Multiplier per additional failure.
        factor: f64,
        /// Upper bound on the un-jittered delay.
        cap: f64,
        /// Consecutive failures tolerated before quarantine.
        max_attempts: u32,
    },
}

impl RetryPolicy {
    /// Validate delays and attempt caps.
    pub fn validate(&self) -> crate::Result<()> {
        match *self {
            RetryPolicy::Immediate { max_attempts } => {
                if max_attempts == 0 {
                    return Err(Error::InvalidParam(
                        "retry max_attempts must be at least 1".into(),
                    ));
                }
            }
            RetryPolicy::ExponentialBackoff { base, factor, cap, max_attempts } => {
                if max_attempts == 0 {
                    return Err(Error::InvalidParam(
                        "retry max_attempts must be at least 1".into(),
                    ));
                }
                for (name, v) in [("base", base), ("factor", factor), ("cap", cap)] {
                    if !v.is_finite() || v <= 0.0 {
                        return Err(Error::InvalidParam(format!(
                            "retry {name} must be positive and finite, got {v}"
                        )));
                    }
                }
            }
        }
        Ok(())
    }

    /// Delay until the retry that follows the `failures`-th consecutive
    /// failure (1-based), or `None` when the attempt budget is spent
    /// and the page must be quarantined. Jitter draws come from `rng`
    /// (the page's fault substream), so replays are deterministic.
    pub(crate) fn next_delay<R: RandomSource>(&self, failures: u32, rng: &mut R) -> Option<f64> {
        match *self {
            RetryPolicy::Immediate { max_attempts } => {
                (failures < max_attempts).then_some(0.0)
            }
            RetryPolicy::ExponentialBackoff { base, factor, cap, max_attempts } => {
                if failures >= max_attempts {
                    return None;
                }
                let raw = (base * factor.powi(failures as i32 - 1)).min(cap);
                let jitter = 0.5 + rng.f64();
                Some(raw * jitter)
            }
        }
    }
}

impl Default for RetryPolicy {
    /// Exponential backoff: 1-unit base, doubling, capped at 60 units,
    /// 4 attempts then quarantine.
    fn default() -> Self {
        RetryPolicy::ExponentialBackoff { base: 1.0, factor: 2.0, cap: 60.0, max_attempts: 4 }
    }
}

/// Deterministic per-run fault source: validated config + per-page RNG
/// substreams + the per-run permanent-dead draw.
///
/// Reusable across repetitions: [`FaultModel::reset`] (called by the
/// engine's `on_start` path) re-derives every substream from the master
/// seed, so one model instance replayed twice produces bit-identical
/// outcome sequences.
#[derive(Debug, Clone)]
pub struct FaultModel {
    cfg: FaultConfig,
    inert: bool,
    /// Per-page fault substream: outcome coins + retry jitter.
    streams: Vec<SplitMix64>,
    /// Per-page permanent-dead flags (drawn once per run).
    dead: Vec<bool>,
}

impl FaultModel {
    /// Validated construction. Substreams are derived lazily by
    /// [`Self::reset`] at the start of every run.
    pub fn new(cfg: FaultConfig) -> crate::Result<Self> {
        cfg.validate()?;
        let inert = cfg.is_inert();
        Ok(Self { cfg, inert, streams: Vec::new(), dead: Vec::new() })
    }

    /// The zero-fault model (cannot fail to validate).
    pub fn inert() -> Self {
        Self { cfg: FaultConfig::none(), inert: true, streams: Vec::new(), dead: Vec::new() }
    }

    /// The validated configuration.
    pub fn config(&self) -> &FaultConfig {
        &self.cfg
    }

    /// No fault source is active: [`Self::outcome`] is `Success`
    /// without a single RNG draw.
    #[inline]
    pub fn is_inert(&self) -> bool {
        self.inert
    }

    /// Host of `page` (the shared round-robin convention,
    /// [`crate::coordinator::hosts::host_of`]).
    #[inline]
    pub fn host_of(&self, page: usize) -> usize {
        host_of(page, self.cfg.hosts)
    }

    /// Number of hosts.
    #[inline]
    pub fn hosts(&self) -> usize {
        self.cfg.hosts
    }

    /// Re-derive the per-page substreams and the permanent-dead draw
    /// for a run over `m` pages (the same master/per-page `split64`
    /// keying discipline as the event sources, so fault draws never
    /// alias trace draws).
    pub fn reset(&mut self, m: usize) {
        self.streams.clear();
        self.dead.clear();
        if self.inert {
            return;
        }
        let mut master = Rng::new(self.cfg.seed);
        self.streams.reserve(m);
        self.dead.reserve(m);
        for i in 0..m {
            let mut s = master.split64(i as u64);
            let dead = self.cfg.gone_prob > 0.0 && s.bernoulli(self.cfg.gone_prob);
            self.streams.push(s);
            self.dead.push(dead);
        }
    }

    /// Was `page` drawn permanently dead this run?
    #[inline]
    pub fn is_dead(&self, page: usize) -> bool {
        !self.inert && self.dead[page]
    }

    /// Is `page`'s host inside an outage window at `t`?
    #[inline]
    pub fn host_dark(&self, page: usize, t: f64) -> bool {
        if self.cfg.outages.is_empty() {
            return false;
        }
        let h = self.host_of(page);
        self.cfg.outages.iter().any(|o| o.covers(h, t))
    }

    /// Outcome of a crawl attempt against `page` at time `t`.
    ///
    /// Draw order is fixed (dead → host-dark → transient coin → timeout
    /// coin → success) so replays are deterministic; the inert fast
    /// path returns `Success` without touching any stream.
    #[inline]
    pub fn outcome(&mut self, page: usize, t: f64) -> CrawlOutcome {
        if self.inert {
            return CrawlOutcome::Success;
        }
        if self.dead[page] {
            return CrawlOutcome::Gone;
        }
        if self.host_dark(page, t) {
            return CrawlOutcome::Timeout;
        }
        let s = &mut self.streams[page];
        if self.cfg.transient_prob > 0.0 && s.bernoulli(self.cfg.transient_prob) {
            return CrawlOutcome::TransientError;
        }
        if self.cfg.timeout_prob > 0.0 && s.bernoulli(self.cfg.timeout_prob) {
            return CrawlOutcome::Timeout;
        }
        CrawlOutcome::Success
    }

    /// The page's fault substream, for retry-jitter draws.
    #[inline]
    pub(crate) fn jitter_stream(&mut self, page: usize) -> &mut SplitMix64 {
        &mut self.streams[page]
    }
}

/// Degraded-mode accounting of one faulty repetition.
///
/// The bandwidth-conservation identity every run satisfies (asserted by
/// the chaos suite): `successes + failures() + forfeited_ticks +
/// idle_ticks == ticks` — one tick buys at most one fetch attempt, so
/// no schedule rate is ever exceeded, retries included.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultStats {
    /// Fetch attempts (successes + failures; retries included).
    pub attempts: u64,
    /// Attempts that succeeded.
    pub successes: u64,
    /// Attempts lost to transient errors.
    pub transient_errors: u64,
    /// Attempts lost to timeouts (incl. host outages).
    pub timeouts: u64,
    /// Attempts against permanently-dead pages.
    pub gone: u64,
    /// Attempts that were retries scheduled by the [`RetryPolicy`].
    pub retries: u64,
    /// Pages quarantined (attempt budget spent, or permanently gone).
    pub quarantined: u64,
    /// Ticks forfeited because the scheduler picked a quarantined page.
    pub forfeited_ticks: u64,
    /// Ticks where nothing was eligible to crawl.
    pub idle_ticks: u64,
    /// Retries per host (round-robin host convention).
    pub retries_per_host: Vec<u64>,
}

impl FaultStats {
    /// Stats sized for a `hosts`-host model.
    pub fn new(hosts: usize) -> Self {
        Self { retries_per_host: vec![0; hosts], ..Self::default() }
    }

    /// Failed attempts (wasted bandwidth ticks).
    pub fn failures(&self) -> u64 {
        self.transient_errors + self.timeouts + self.gone
    }

    /// Fraction of the spent fetch bandwidth that was wasted on failed
    /// attempts (0 when nothing was attempted).
    pub fn wasted_fraction(&self) -> f64 {
        if self.attempts == 0 {
            0.0
        } else {
            self.failures() as f64 / self.attempts as f64
        }
    }

    /// Publish the ledger into a metrics registry under the `fault_`
    /// prefix. Counters *add* (repetition loops accumulate across
    /// runs); the wasted-bandwidth gauge is overwritten with this
    /// ledger's value.
    pub fn export(&self, registry: &crate::metrics::Registry) {
        registry.counter("fault_attempts").add(self.attempts);
        registry.counter("fault_successes").add(self.successes);
        registry.counter("fault_transient_errors").add(self.transient_errors);
        registry.counter("fault_timeouts").add(self.timeouts);
        registry.counter("fault_gone").add(self.gone);
        registry.counter("fault_retries").add(self.retries);
        registry.counter("fault_quarantined").add(self.quarantined);
        registry.counter("fault_forfeited_ticks").add(self.forfeited_ticks);
        registry.counter("fault_idle_ticks").add(self.idle_ticks);
        registry.gauge("fault_wasted_fraction").set(self.wasted_fraction());
    }
}

/// Politeness-style decorator that reroutes picks away from hosts
/// inside a *known* outage window (e.g. published maintenance windows
/// or an operator-fed outage feed): a pick on a dark host is vetoed via
/// the existing `on_veto` machinery — the inner scheduler then yields
/// its next-best candidate — a bounded number of times per tick.
///
/// Unknown (unannounced) outages still surface as [`CrawlOutcome::Timeout`]
/// through the [`FaultModel`]; this decorator is the *mitigation* for
/// the announced subset, measured in `figure faults`.
pub struct OutageAwareScheduler<S> {
    inner: S,
    outages: Vec<HostOutage>,
    hosts: usize,
    /// Diagnostics: picks rerouted off dark hosts.
    pub rerouted: u64,
    /// Diagnostics: ticks idled because every candidate was dark.
    pub dark_idle_ticks: u64,
}

impl<S: CrawlScheduler> OutageAwareScheduler<S> {
    /// Wrap `inner`, avoiding the given outage windows over a
    /// `hosts`-host population (`page % hosts` round-robin).
    pub fn new(inner: S, outages: Vec<HostOutage>, hosts: usize) -> Self {
        assert!(hosts > 0, "at least one host required");
        Self { inner, outages, hosts, rerouted: 0, dark_idle_ticks: 0 }
    }

    fn dark(&self, page: usize, t: f64) -> bool {
        let h = host_of(page, self.hosts);
        self.outages.iter().any(|o| o.covers(h, t))
    }

    /// Access the wrapped scheduler.
    pub fn inner(&self) -> &S {
        &self.inner
    }
}

impl<S: CrawlScheduler> CrawlScheduler for OutageAwareScheduler<S> {
    fn on_start(&mut self, m: usize) {
        self.inner.on_start(m);
        self.rerouted = 0;
        self.dark_idle_ticks = 0;
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        const MAX_RETRIES: usize = 8;
        for _ in 0..MAX_RETRIES {
            let pick = self.inner.select(t)?;
            if !self.dark(pick, t) {
                return Some(pick);
            }
            self.rerouted += 1;
            self.inner.on_veto(pick, t);
        }
        self.dark_idle_ticks += 1;
        None
    }

    fn on_cis(&mut self, page: usize, t: f64) {
        self.inner.on_cis(page, t);
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        self.inner.on_crawl(page, t);
    }

    fn on_veto(&mut self, page: usize, t: f64) {
        self.inner.on_veto(page, t);
    }

    fn on_crawl_failed(&mut self, page: usize, t: f64, outcome: CrawlOutcome) {
        self.inner.on_crawl_failed(page, t, outcome);
    }

    fn on_fetch_observed(&mut self, page: usize, t: f64, changed: bool) {
        self.inner.on_fetch_observed(page, t, changed);
    }

    fn on_page_added(&mut self, page: usize, params: &crate::params::PageParams, t: f64) {
        self.inner.on_page_added(page, params, t);
    }

    fn on_page_removed(&mut self, page: usize, t: f64) {
        self.inner.on_page_removed(page, t);
    }

    fn on_params_changed(&mut self, page: usize, params: &crate::params::PageParams, t: f64) {
        self.inner.on_params_changed(page, params, t);
    }

    fn attach_trace(&mut self, tr: crate::trace::TraceHandle) {
        self.inner.attach_trace(tr);
    }

    fn name(&self) -> String {
        format!("{}-OUTAGE-AWARE", self.inner.name())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn config_validation_rejects_bad_inputs() {
        assert!(FaultConfig::none().validate().is_ok());
        let bad_p = FaultConfig { transient_prob: 1.5, ..FaultConfig::none() };
        assert!(bad_p.validate().is_err(), "probability > 1");
        let nan_p = FaultConfig { timeout_prob: f64::NAN, ..FaultConfig::none() };
        assert!(nan_p.validate().is_err(), "NaN probability");
        let neg_p = FaultConfig { gone_prob: -0.1, ..FaultConfig::none() };
        assert!(neg_p.validate().is_err(), "negative probability");
        let no_hosts = FaultConfig { hosts: 0, ..FaultConfig::none() };
        assert!(no_hosts.validate().is_err(), "zero hosts");
        let bad_outage = FaultConfig {
            outages: vec![HostOutage { host: 3, start: 0.0, end: 1.0 }],
            ..FaultConfig::none()
        };
        assert!(bad_outage.validate().is_err(), "outage host out of range");
        let empty_window = FaultConfig {
            outages: vec![HostOutage { host: 0, start: 2.0, end: 2.0 }],
            ..FaultConfig::none()
        };
        assert!(empty_window.validate().is_err(), "empty outage window");
    }

    #[test]
    fn inert_model_never_draws() {
        let mut m = FaultModel::new(FaultConfig::none()).expect("zero-fault config is valid");
        assert!(m.is_inert());
        m.reset(16);
        for page in 0..16 {
            for k in 0..10 {
                assert_eq!(m.outcome(page, k as f64), CrawlOutcome::Success);
            }
            assert!(!m.is_dead(page));
        }
    }

    #[test]
    fn outcomes_are_replay_deterministic() {
        let cfg = FaultConfig {
            transient_prob: 0.3,
            timeout_prob: 0.2,
            gone_prob: 0.05,
            hosts: 4,
            outages: vec![HostOutage { host: 1, start: 2.0, end: 5.0 }],
            seed: 99,
        };
        let run = || {
            let mut m = FaultModel::new(cfg.clone()).expect("valid config");
            m.reset(32);
            let mut seq = Vec::new();
            for k in 0..200 {
                let page = k % 32;
                seq.push(m.outcome(page, k as f64 * 0.1));
            }
            seq
        };
        assert_eq!(run(), run(), "same seed must replay bit-identically");
    }

    #[test]
    fn model_reset_restores_the_stream() {
        let cfg = FaultConfig { transient_prob: 0.4, seed: 7, ..FaultConfig::none() };
        let mut m = FaultModel::new(cfg).expect("valid config");
        m.reset(8);
        let a: Vec<CrawlOutcome> = (0..50).map(|k| m.outcome(k % 8, k as f64)).collect();
        m.reset(8);
        let b: Vec<CrawlOutcome> = (0..50).map(|k| m.outcome(k % 8, k as f64)).collect();
        assert_eq!(a, b, "reset must rewind the fault streams");
    }

    #[test]
    fn dead_pages_are_always_gone() {
        let cfg = FaultConfig { gone_prob: 0.5, seed: 3, ..FaultConfig::none() };
        let mut m = FaultModel::new(cfg).expect("valid config");
        m.reset(64);
        let dead: Vec<usize> = (0..64).filter(|&i| m.is_dead(i)).collect();
        assert!(!dead.is_empty() && dead.len() < 64, "gone_prob=0.5 should split the pages");
        for &i in &dead {
            assert_eq!(m.outcome(i, 1.0), CrawlOutcome::Gone);
            assert_eq!(m.outcome(i, 2.0), CrawlOutcome::Gone, "gone is permanent");
        }
    }

    #[test]
    fn host_outage_times_out_the_whole_host() {
        let mut cfg = FaultConfig { hosts: 4, ..FaultConfig::none() };
        cfg.outages.push(HostOutage { host: 2, start: 10.0, end: 20.0 });
        let mut m = FaultModel::new(cfg).expect("valid config");
        m.reset(8);
        // pages 2 and 6 live on host 2
        for page in [2usize, 6] {
            assert_eq!(m.outcome(page, 15.0), CrawlOutcome::Timeout, "dark host must time out");
            assert_eq!(m.outcome(page, 9.9), CrawlOutcome::Success, "before the window");
            assert_eq!(m.outcome(page, 20.0), CrawlOutcome::Success, "window end exclusive");
        }
        assert_eq!(m.outcome(1, 15.0), CrawlOutcome::Success, "other hosts unaffected");
    }

    #[test]
    fn correlated_outage_generator_is_deterministic_and_in_range() {
        let mut a = FaultConfig { hosts: 5, ..FaultConfig::none() };
        a.add_correlated_outages(10, 3.0, 100.0, 42);
        let mut b = FaultConfig { hosts: 5, ..FaultConfig::none() };
        b.add_correlated_outages(10, 3.0, 100.0, 42);
        assert_eq!(a.outages, b.outages);
        assert_eq!(a.outages.len(), 10);
        for (i, o) in a.outages.iter().enumerate() {
            assert_eq!(o.host, i % 5, "hosts hit round-robin");
            assert!(o.start >= 0.0 && o.end <= 100.0 && o.end > o.start);
        }
        assert!(a.validate().is_ok());
    }

    #[test]
    fn retry_policy_validates_and_caps_attempts() {
        assert!(RetryPolicy::default().validate().is_ok());
        assert!(RetryPolicy::Immediate { max_attempts: 0 }.validate().is_err());
        assert!(RetryPolicy::ExponentialBackoff {
            base: 0.0,
            factor: 2.0,
            cap: 1.0,
            max_attempts: 3
        }
        .validate()
        .is_err());
        let mut rng = SplitMix64::new(1);
        let p = RetryPolicy::Immediate { max_attempts: 3 };
        assert_eq!(p.next_delay(1, &mut rng), Some(0.0));
        assert_eq!(p.next_delay(2, &mut rng), Some(0.0));
        assert_eq!(p.next_delay(3, &mut rng), None, "budget spent → quarantine");
    }

    #[test]
    fn backoff_grows_caps_and_jitters_deterministically() {
        let p = RetryPolicy::ExponentialBackoff {
            base: 1.0,
            factor: 2.0,
            cap: 5.0,
            max_attempts: 10,
        };
        let mut rng = SplitMix64::new(9);
        let delays: Vec<f64> =
            (1..=6).map(|k| p.next_delay(k, &mut rng).expect("within budget")).collect();
        // jitter is in [0.5, 1.5): delay k lives in [raw/2, 3·raw/2)
        for (k, d) in delays.iter().enumerate() {
            let raw = (2.0f64).powi(k as i32).min(5.0);
            assert!(
                (raw * 0.5..raw * 1.5).contains(d),
                "delay {k}: {d} outside jitter band of raw {raw}"
            );
        }
        // deterministic replay from an identically-seeded stream
        let mut rng2 = SplitMix64::new(9);
        let replay: Vec<f64> =
            (1..=6).map(|k| p.next_delay(k, &mut rng2).expect("within budget")).collect();
        assert_eq!(
            delays.iter().map(|d| d.to_bits()).collect::<Vec<_>>(),
            replay.iter().map(|d| d.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn stats_identities_hold() {
        let mut s = FaultStats::new(3);
        assert_eq!(s.wasted_fraction(), 0.0, "no attempts → nothing wasted");
        s.attempts = 10;
        s.successes = 7;
        s.transient_errors = 2;
        s.timeouts = 1;
        assert_eq!(s.failures(), 3);
        assert!((s.wasted_fraction() - 0.3).abs() < 1e-12);
        assert_eq!(s.retries_per_host.len(), 3);
    }

    #[test]
    fn outage_aware_decorator_reroutes_off_dark_hosts() {
        // inner always proposes pages 0, 1, 2, ... in order; host 0
        // (pages 0, 2) is dark at t = 5 → the decorator must surface
        // page 1 (host 1) after vetoing page 0
        struct Seq(usize);
        impl CrawlScheduler for Seq {
            fn select(&mut self, _t: f64) -> Option<usize> {
                let i = self.0;
                self.0 += 1;
                Some(i)
            }
            fn on_veto(&mut self, _page: usize, _t: f64) {}
        }
        let outages = vec![HostOutage { host: 0, start: 0.0, end: 10.0 }];
        let mut s = OutageAwareScheduler::new(Seq(0), outages.clone(), 2);
        assert_eq!(s.select(5.0), Some(1), "pick rerouted to the lit host");
        assert_eq!(s.rerouted, 1);
        // outside the window the first pick passes through
        let mut s2 = OutageAwareScheduler::new(Seq(0), outages, 2);
        assert_eq!(s2.select(20.0), Some(0));
        assert_eq!(s2.rerouted, 0);
        assert!(s2.name().ends_with("-OUTAGE-AWARE"));
    }
}
