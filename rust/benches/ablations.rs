//! Ablation benches for the design choices DESIGN.md calls out:
//!   (a) lazy-scheduler margin sweep (accuracy vs evaluations),
//!   (b) approximation level J (accuracy vs per-eval cost),
//!   (c) shard count (accuracy loss from the 1/N bandwidth split),
//!   (d) politeness interval (freshness cost of per-host courtesy).
//!
//! `cargo bench --bench ablations` — series land in target/figures/.

use ncis_crawl::benchkit::FigureOutput;
use ncis_crawl::coordinator::hosts::{HostMap, PoliteScheduler};
use ncis_crawl::coordinator::lazy::LazyGreedyScheduler;
use ncis_crawl::coordinator::shard::{run_sharded, ShardPlan};
use ncis_crawl::figures::common::ExperimentSpec;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::sim::{generate_traces, simulate, CisDelay, SimConfig};
use ncis_crawl::{CrawlerBuilder, Strategy};

fn main() {
    let spec = ExperimentSpec::section6(800, 1).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();
    let horizon = 200.0;
    let r = 50.0;
    let cfg = SimConfig::new(r, horizon).unwrap();
    let mut trng = Rng::new(99);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);

    // (a) margin sweep (concrete type: the eval counters are diagnostics
    // the trait object does not expose)
    let mut fig = FigureOutput::new("ablation_lazy_margin", &["margin", "accuracy", "evals_per_tick"]);
    for &margin in &[0.3, 0.5, 0.7, 0.9, 1.0] {
        let mut lz = LazyGreedyScheduler::with_margin(PolicyKind::GreedyNcis, &inst.pages, margin);
        let res = simulate(&traces, &cfg, &mut lz);
        fig.rowf(&[margin, res.accuracy, lz.evals as f64 / lz.ticks as f64]);
    }
    let mut ex = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Exact)
        .pages(&inst.pages)
        .build()
        .unwrap();
    let res = simulate(&traces, &cfg, ex.as_mut());
    fig.rowf(&[f64::NAN, res.accuracy, inst.pages.len() as f64]); // exact reference
    fig.finish().unwrap();

    // (b) J sweep
    let mut fig = FigureOutput::new("ablation_terms", &["J", "accuracy"]);
    for &j in &[1u32, 2, 4, 8, 64] {
        let kind = if j >= 64 { PolicyKind::GreedyNcis } else { PolicyKind::NcisApprox(j) };
        let mut s = CrawlerBuilder::new()
            .policy(kind)
            .strategy(Strategy::Exact)
            .pages(&inst.pages)
            .build()
            .unwrap();
        let res = simulate(&traces, &cfg, s.as_mut());
        fig.rowf(&[j as f64, res.accuracy]);
    }
    fig.finish().unwrap();

    // (c) shard count
    let mut fig = FigureOutput::new("ablation_shards", &["shards", "accuracy"]);
    for &n in &[1usize, 2, 4, 8, 16] {
        let run = run_sharded(
            &inst.pages,
            &ShardPlan::round_robin(inst.pages.len(), n),
            PolicyKind::GreedyNcis,
            r,
            horizon,
            7,
        )
        .unwrap();
        fig.rowf(&[n as f64, run.accuracy]);
    }
    fig.finish().unwrap();

    // (d) politeness interval
    let mut fig = FigureOutput::new("ablation_politeness", &["min_interval", "accuracy", "vetoes"]);
    for &w in &[0.0, 0.05, 0.2, 0.5, 1.0] {
        let map = HostMap::round_robin(inst.pages.len(), 20, w);
        let inner = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Exact)
            .pages(&inst.pages)
            .build()
            .unwrap();
        let mut polite = PoliteScheduler::new(inner, map);
        let res = simulate(&traces, &cfg, &mut polite);
        fig.rowf(&[w, res.accuracy, polite.vetoes as f64]);
    }
    fig.finish().unwrap();
}
