//! Figure-regeneration bench: one entry per paper figure (DESIGN.md).
//!
//! `cargo bench --bench figures` regenerates every table/figure series
//! into `target/figures/*.csv`. Repetition counts default to a
//! laptop-scale budget; set `NCIS_REPS` to raise them toward the
//! paper's 100 (see EXPERIMENTS.md for the scaling rationale).
//!
//! Select a subset: `cargo bench --bench figures -- 2 3 4`.

fn reps() -> usize {
    std::env::var("NCIS_REPS").ok().and_then(|v| v.parse().ok()).unwrap_or(10)
}

fn main() {
    let args: Vec<String> = std::env::args().skip(1).filter(|a| !a.starts_with('-')).collect();
    let all =
        ["1", "2", "3", "6", "7", "8", "9", "10", "11", "12", "14", "4", "5", "appg", "scenario"];
    let ids: Vec<&str> = if args.is_empty() {
        all.to_vec()
    } else {
        all.iter().copied().filter(|id| args.iter().any(|a| a == id)).collect()
    };
    let r = reps();
    println!(
        "figure bench: ids={ids:?} reps={r} (NCIS_REPS to override; cells fan reps \
         across {} threads, NCIS_THREADS to override)",
        ncis_crawl::figures::common::default_rep_threads()
    );
    for id in ids {
        let t0 = std::time::Instant::now();
        match ncis_crawl::figures::run_figure(id, r) {
            Ok(()) => println!("figure {id}: done in {:?}\n", t0.elapsed()),
            Err(e) => {
                eprintln!("figure {id}: FAILED: {e}");
                std::process::exit(1);
            }
        }
    }
}
