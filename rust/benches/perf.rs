//! Performance benches (EXPERIMENTS.md §Perf):
//!
//! - value-function evaluation throughput (native f64)
//! - batched crawl values: PJRT (AOT Pallas kernel) vs native, by batch
//! - scheduler tick cost: exact argmax vs the §5.2 lazy scheduler
//! - end-to-end simulation throughput
//! - approximation-level ablation (J ∈ {1, 2, 4, 8})

use ncis_crawl::benchkit::{measure, report};
use ncis_crawl::coordinator::crawler::{GreedyScheduler, ValueBackend};
use ncis_crawl::coordinator::lazy::LazyGreedyScheduler;
use ncis_crawl::figures::common::ExperimentSpec;
use ncis_crawl::params::DerivedParams;
use ncis_crawl::policy::{value, PolicyKind};
use ncis_crawl::rngkit::Rng;
use ncis_crawl::runtime::{NativeEngine, PjrtEngine, ValueBatch};
use ncis_crawl::sim::{generate_traces, simulate, CisDelay, SimConfig};

fn bench_value_functions() {
    println!("\n-- value-function evaluation (native f64) --");
    let mut rng = Rng::new(1);
    let envs: Vec<DerivedParams> = (0..1024)
        .map(|_| {
            ncis_crawl::params::PageParams {
                delta: rng.range(0.01, 1.0),
                mu: rng.range(0.01, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.6),
            }
            .derive()
            .unwrap()
        })
        .collect();
    let iotas: Vec<f64> = (0..1024).map(|_| 10f64.powf(rng.range(-2.0, 1.5))).collect();
    for terms in [1u32, 2, 4, 8, value::MAX_TERMS] {
        let mut k = 0usize;
        let m = measure(
            || {
                let v = value::value_ncis(iotas[k & 1023], &envs[k & 1023], terms);
                std::hint::black_box(v);
                k += 1;
            },
            5,
            0.05,
        );
        report(&format!("value_ncis terms={terms}"), &m);
    }
}

fn bench_batched_values() {
    println!("\n-- batched crawl values: PJRT vs native --");
    let engine = PjrtEngine::load(std::path::Path::new("artifacts")).ok();
    if engine.is_none() {
        println!("(artifacts not built; skipping PJRT lanes)");
    }
    let native = NativeEngine;
    let mut rng = Rng::new(2);
    for &n in &[2048usize, 16384] {
        let mut batch = ValueBatch::with_capacity(n);
        for _ in 0..n {
            let d = ncis_crawl::params::PageParams {
                delta: rng.range(0.01, 1.0),
                mu: rng.range(0.01, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.6),
            }
            .derive()
            .unwrap();
            batch.push(10f64.powf(rng.range(-2.0, 1.5)), &d);
        }
        for terms in [2u32, 8] {
            let m = measure(
                || {
                    std::hint::black_box(native.crawl_values(terms, &batch));
                },
                5,
                0.1,
            );
            report(&format!("native  batch={n} terms={terms}"), &m);
            println!("{:>46} {:.1}M pages/s", "", m.per_second(n as f64) / 1e6);
            if let Some(eng) = &engine {
                let m = measure(
                    || {
                        std::hint::black_box(eng.crawl_values(terms, &batch).unwrap());
                    },
                    5,
                    0.1,
                );
                report(&format!("pjrt    batch={n} terms={terms}"), &m);
                println!("{:>46} {:.1}M pages/s", "", m.per_second(n as f64) / 1e6);
            }
        }
    }
}

fn bench_schedulers() {
    println!("\n-- scheduler tick cost: exact vs lazy (m=5000) --");
    let spec = ExperimentSpec::section6(5000, 1).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(3);
    let inst = spec.gen_instance(&mut rng).normalized();
    let horizon = 20.0;
    let r = 100.0;
    let mut trng = Rng::new(4);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(r, horizon);

    let m_exact = measure(
        || {
            let mut s = GreedyScheduler::new(PolicyKind::GreedyNcis, &inst.pages, ValueBackend::Native);
            std::hint::black_box(simulate(&traces, &cfg, &mut s));
        },
        3,
        0.2,
    );
    report("simulate 2000 ticks, exact argmax", &m_exact);
    let m_lazy = measure(
        || {
            let mut s = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &inst.pages);
            std::hint::black_box(simulate(&traces, &cfg, &mut s));
        },
        3,
        0.2,
    );
    report("simulate 2000 ticks, lazy scheduler", &m_lazy);
    println!(
        "lazy speedup: {:.1}x   (ticks/s: exact {:.0}, lazy {:.0})",
        m_exact.mean_s / m_lazy.mean_s,
        2000.0 / m_exact.mean_s,
        2000.0 / m_lazy.mean_s
    );
    // eval-count diagnostic
    let mut s = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &inst.pages);
    simulate(&traces, &cfg, &mut s);
    println!(
        "lazy evals/tick: {:.1} (exact would be {})",
        s.evals as f64 / s.ticks as f64,
        inst.pages.len()
    );
}

fn bench_end_to_end() {
    println!("\n-- end-to-end simulation throughput (m=1000, R=100, T=100) --");
    let spec = ExperimentSpec::section6(1000, 1).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(5);
    let inst = spec.gen_instance(&mut rng).normalized();
    let mut trng = Rng::new(6);
    let traces = generate_traces(&inst.pages, 100.0, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(100.0, 100.0);
    let m = measure(
        || {
            let mut s = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &inst.pages);
            std::hint::black_box(simulate(&traces, &cfg, &mut s));
        },
        3,
        0.3,
    );
    report("lazy GREEDY-NCIS full rep (10k ticks)", &m);
    println!("{:>46} {:.0}k ticks/s", "", 10.0 / m.mean_s);
}

fn main() {
    println!("perf bench (see EXPERIMENTS.md §Perf)");
    bench_value_functions();
    bench_batched_values();
    bench_schedulers();
    bench_end_to_end();
}
