//! Performance benches (EXPERIMENTS.md §Perf):
//!
//! - value-function evaluation throughput (native f64)
//! - batched crawl values: PJRT (AOT Pallas kernel) vs native, by batch
//! - select-heavy argmax: the scalar full-scan reference vs the
//!   batched/bound-pruned columnar path at m ∈ {1e4, 1e5} (the
//!   columnar-hot-path acceptance lane)
//! - wake calendar: `BinaryHeap` vs the hierarchical `TimingWheel`
//! - scheduler tick cost: exact argmax vs the §5.2 lazy scheduler
//! - dynamic-world cost: the `scenario_churn` lanes — lazy
//!   select+advance under steady page churn (ρ sweep at m = 1e5) vs
//!   the static-world engine (acceptance: ≤ 2× at ρ = 1%)
//! - end-to-end simulation throughput
//! - experiment-cell wall clock: pre-change serial merged-sort engine vs
//!   the streaming engine + parallel repetition driver (the acceptance
//!   lane: m=1000, R=100, T=1000, 8 reps, GREEDY + LAZY)
//! - event sourcing: materialized vs streamed generation peak memory
//!   (`gen_*` lanes, counting-allocator live-bytes high-water;
//!   acceptance: streamed ≤ 10% at the largest m), replay-vs-streamed
//!   simulation throughput (`sim_{materialized,streamed}_*`;
//!   acceptance: ≤ 1.2× at m=1e5), and one full streamed repetition at
//!   m=1e6 (`sim_streamed_m1000000`)
//! - fault layer: the fault engine with the zero-fault model vs the
//!   plain engine (`fault_overhead_*`; acceptance: ≤ 1.05× at m=1e5)
//!   and degraded-mode throughput under a heavy fault mix
//!   (`fault_degraded_*`)
//! - serving layer: the served engine with off traffic vs the plain
//!   engine (`serve_off_*` / `serve_overhead_*`; acceptance: ≤ 1.10×
//!   at m=1e5) and loaded Zipf request throughput (`serve_on_*`)
//! - estimation loop: oracle vs learned knowledge on the same cell
//!   (`est_{oracle,learned}_*` and the `est_overhead_*` ratio;
//!   acceptance: ≤ 1.25× at m=1e5)
//! - flight recorder: the traced engine entry with no handle vs the
//!   plain engine (`trace_off_*` / `trace_overhead_*`; acceptance:
//!   ≤ 1.02× at m=1e5) and full ring-buffer recording
//!   (`trace_on_*`; acceptance: ≤ 1.25× at m=1e5)
//! - scenario DSL: parse+compile throughput of a DSL world at m=1e5
//!   (`world_parse_m*`), the DSL-compiled world replayed against its
//!   hand-constructed bit-identical twin (`world_overhead_m*`;
//!   acceptance: ≤ 1.05×), and the fuzz campaign's sustained world
//!   rate (`fuzz_rep_rate`)
//!
//! Every lane is also recorded into `BENCH_perf.json` (via
//! `benchkit::BenchJson`) so future PRs have a machine-readable perf
//! trajectory, and `main` fails (non-zero exit, so CI fails the job)
//! if any declared acceptance lane is missing from the file. Scale the
//! acceptance cell down on small machines with `NCIS_PERF_M` /
//! `NCIS_PERF_T` / `NCIS_PERF_REPS` and the memory lanes with
//! `NCIS_GEN_M` / `NCIS_GEN_T`, or pass `--smoke`
//! (`cargo bench --bench perf -- --smoke`) for the CI-sized run that
//! exercises every lane at tiny m.

use std::time::Instant;

use ncis_crawl::benchkit::mem::{self, MemSpan};
use ncis_crawl::benchkit::{measure, report, BenchJson};
use ncis_crawl::coordinator::crawler::{GreedyScheduler, ValueBackend};
use ncis_crawl::coordinator::lazy::LazyGreedyScheduler;
use ncis_crawl::figures::common::{
    default_rep_threads, make_scheduler, run_cell_with_threads, ExperimentSpec, PolicyUnderTest,
};
use ncis_crawl::params::DerivedParams;
use ncis_crawl::policy::{value, PolicyKind};
use ncis_crawl::rngkit::Rng;
use ncis_crawl::runtime::{NativeEngine, PjrtEngine, ValueBatch};
use ncis_crawl::scenario::generators::{add_steady_churn, BornPageSpec};
use ncis_crawl::scenario::{simulate_scenario_with, Scenario, ScenarioWorkspace};
use ncis_crawl::sched::wheel::TimingWheel;
use ncis_crawl::sched::CrawlScheduler;
use ncis_crawl::sim::metrics::RepAccumulator;
use ncis_crawl::sim::{
    generate_traces, simulate, simulate_reference, simulate_streamed_with, simulate_with,
    CisDelay, EventSource, SimConfig, SimWorkspace, StreamedSource, TraceMode,
};
use ncis_crawl::util::OrdF64;
use ncis_crawl::{CrawlerBuilder, Strategy};

// The memory lanes (`gen_*`) need real allocation accounting: install
// the counting allocator for the whole bench binary.
#[global_allocator]
static COUNTING_ALLOC: mem::CountingAlloc = mem::CountingAlloc;

fn env_usize(key: &str, default: usize) -> usize {
    std::env::var(key).ok().and_then(|v| v.parse().ok()).unwrap_or(default)
}

fn bench_value_functions(json: &mut BenchJson) {
    println!("\n-- value-function evaluation (native f64) --");
    let mut rng = Rng::new(1);
    let envs: Vec<DerivedParams> = (0..1024)
        .map(|_| {
            ncis_crawl::params::PageParams {
                delta: rng.range(0.01, 1.0),
                mu: rng.range(0.01, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.6),
            }
            .derive()
            .unwrap()
        })
        .collect();
    let iotas: Vec<f64> = (0..1024).map(|_| 10f64.powf(rng.range(-2.0, 1.5))).collect();
    for terms in [1u32, 2, 4, 8, value::MAX_TERMS] {
        let mut k = 0usize;
        let m = measure(
            || {
                let v = value::value_ncis(iotas[k & 1023], &envs[k & 1023], terms);
                std::hint::black_box(v);
                k += 1;
            },
            5,
            0.05,
        );
        report(&format!("value_ncis terms={terms}"), &m);
        json.lane(
            &format!("value_ncis_terms_{terms}"),
            &[("ns_per_eval", m.mean_s * 1e9), ("evals_per_s", m.per_second(1.0))],
        );
    }
}

fn bench_batched_values(json: &mut BenchJson) {
    println!("\n-- batched crawl values: PJRT vs native --");
    let engine = PjrtEngine::load(std::path::Path::new("artifacts")).ok();
    if engine.is_none() {
        println!("(artifacts not built; skipping PJRT lanes)");
    }
    let native = NativeEngine;
    let mut rng = Rng::new(2);
    for &n in &[2048usize, 16384] {
        let mut batch = ValueBatch::with_capacity(n);
        for _ in 0..n {
            let d = ncis_crawl::params::PageParams {
                delta: rng.range(0.01, 1.0),
                mu: rng.range(0.01, 1.0),
                lam: rng.f64(),
                nu: rng.range(0.1, 0.6),
            }
            .derive()
            .unwrap();
            batch.push(10f64.powf(rng.range(-2.0, 1.5)), &d);
        }
        for terms in [2u32, 8] {
            let m = measure(
                || {
                    std::hint::black_box(native.crawl_values(terms, &batch));
                },
                5,
                0.1,
            );
            report(&format!("native  batch={n} terms={terms}"), &m);
            println!("{:>46} {:.1}M pages/s", "", m.per_second(n as f64) / 1e6);
            json.lane(
                &format!("native_batch_{n}_terms_{terms}"),
                &[("pages_per_s", m.per_second(n as f64))],
            );
            if let Some(eng) = &engine {
                let m = measure(
                    || {
                        std::hint::black_box(eng.crawl_values(terms, &batch).unwrap());
                    },
                    5,
                    0.1,
                );
                report(&format!("pjrt    batch={n} terms={terms}"), &m);
                println!("{:>46} {:.1}M pages/s", "", m.per_second(n as f64) / 1e6);
                json.lane(
                    &format!("pjrt_batch_{n}_terms_{terms}"),
                    &[("pages_per_s", m.per_second(n as f64))],
                );
            }
        }
    }
}

/// Select-heavy argmax lanes: the acceptance criterion of the columnar
/// hot-path PR. Both lanes drive the SAME scheduler state transitions
/// (select → crawl the pick → advance one tick), differing only in the
/// evaluation path: `select_scalar_reference` is the pre-columnar full
/// O(m) scalar scan kept in-tree as the oracle; `select` is the batched
/// columnar kernel + bound-pruned fused argmax.
fn bench_select_argmax(json: &mut BenchJson, smoke: bool) {
    let ms: &[usize] = if smoke { &[1024] } else { &[10_000, 100_000] };
    for &m in ms {
        println!("\n-- select-heavy argmax: scalar reference vs batched (m={m}) --");
        let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
        let mut rng = Rng::new(11);
        let inst = spec.gen_instance(&mut rng).normalized();
        let dt = 0.01; // R = 100 tick spacing
        let mut lanes = Vec::new();
        for scalar in [true, false] {
            let mut s =
                GreedyScheduler::new(PolicyKind::GreedyNcis, &inst.pages, ValueBackend::Native);
            s.on_start(inst.pages.len());
            // warm into steady state (same path as the timed loop)
            let mut t = 0.0;
            for _ in 0..64 {
                t += dt;
                let pick = if scalar { s.select_scalar_reference(t) } else { s.select(t) };
                if let Some(i) = pick {
                    s.on_crawl(i, t);
                }
            }
            let meas = measure(
                || {
                    t += dt;
                    let pick = if scalar { s.select_scalar_reference(t) } else { s.select(t) };
                    if let Some(i) = pick {
                        s.on_crawl(i, t);
                    }
                },
                5,
                0.1,
            );
            let label = if scalar { "scalar" } else { "batched" };
            report(&format!("{label:>8} select m={m}"), &meas);
            println!("{:>46} {:.1}k selects/s", "", meas.per_second(1.0) / 1e3);
            json.lane(
                &format!("select_{label}_m{m}"),
                &[("seconds_per_select", meas.mean_s), ("selects_per_s", meas.per_second(1.0))],
            );
            lanes.push(meas.mean_s);
        }
        let speedup = lanes[0] / lanes[1].max(1e-12);
        println!("batched argmax speedup at m={m}: {speedup:.1}x");
        json.lane(&format!("select_speedup_m{m}"), &[("x", speedup)]);
    }
}

/// Wake-calendar lanes: `BinaryHeap` vs the hierarchical `TimingWheel`
/// on the lazy scheduler's workload shape — schedule a population of
/// wakes, then repeatedly advance time, drain the due set and reschedule
/// each drained entry into the future.
fn bench_calendar(json: &mut BenchJson, smoke: bool) {
    let n: usize = if smoke { 2_048 } else { 65_536 };
    let steps: usize = if smoke { 64 } else { 256 };
    println!("\n-- wake calendar: BinaryHeap vs TimingWheel (n={n}, {steps} drains/pass) --");
    // pre-generate the deterministic wake offsets both calendars replay
    let mut rng = Rng::new(17);
    let offsets: Vec<f64> = (0..n * 4).map(|_| 10f64.powf(rng.range(-1.5, 2.5))).collect();
    let dt = 0.25f64;

    let m_heap = {
        let mut heap: std::collections::BinaryHeap<std::cmp::Reverse<(OrdF64, u32, u32)>> =
            std::collections::BinaryHeap::new();
        let mut oi = 0usize;
        measure(
            || {
                heap.clear();
                let mut t = 0.0f64;
                let mut ver = 0u32;
                for p in 0..n as u32 {
                    heap.push(std::cmp::Reverse((OrdF64(offsets[oi % offsets.len()]), ver, p)));
                    oi += 1;
                }
                for _ in 0..steps {
                    t += dt;
                    while let Some(&std::cmp::Reverse((OrdF64(wt), _, p))) = heap.peek() {
                        if wt > t {
                            break;
                        }
                        heap.pop();
                        ver = ver.wrapping_add(1);
                        let off = offsets[oi % offsets.len()];
                        oi += 1;
                        heap.push(std::cmp::Reverse((OrdF64(t + off), ver, p)));
                    }
                }
                std::hint::black_box(heap.len());
            },
            5,
            0.1,
        )
    };
    report("calendar: BinaryHeap", &m_heap);
    json.lane("calendar_heap", &[("seconds_per_pass", m_heap.mean_s)]);

    let m_wheel = {
        let mut wheel = TimingWheel::new(1.0 / 64.0);
        let mut due = Vec::new();
        let mut oi = 0usize;
        measure(
            || {
                wheel.reset();
                let mut t = 0.0f64;
                let mut ver = 0u32;
                for p in 0..n as u32 {
                    wheel.schedule(offsets[oi % offsets.len()], ver, p);
                    oi += 1;
                }
                for _ in 0..steps {
                    t += dt;
                    due.clear();
                    wheel.drain_due_into(t, &mut due);
                    for e in &due {
                        ver = ver.wrapping_add(1);
                        let off = offsets[oi % offsets.len()];
                        oi += 1;
                        wheel.schedule(t + off, ver, e.page);
                    }
                }
                std::hint::black_box(wheel.len());
            },
            5,
            0.1,
        )
    };
    report("calendar: TimingWheel", &m_wheel);
    json.lane("calendar_wheel", &[("seconds_per_pass", m_wheel.mean_s)]);
    println!("wheel speedup: {:.2}x", m_heap.mean_s / m_wheel.mean_s.max(1e-12));
    json.lane("calendar_speedup", &[("x", m_heap.mean_s / m_wheel.mean_s.max(1e-12))]);
}

fn bench_schedulers(json: &mut BenchJson, smoke: bool) {
    let m = if smoke { 400 } else { 5000 };
    println!("\n-- scheduler tick cost: exact vs lazy (m={m}) --");
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(3);
    let inst = spec.gen_instance(&mut rng).normalized();
    let horizon = 20.0;
    let r = 100.0;
    let mut trng = Rng::new(4);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(r, horizon).unwrap();

    let exact_builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Exact)
        .pages(&inst.pages);
    let lazy_builder = exact_builder.clone().strategy(Strategy::Lazy);
    let m_exact = measure(
        || {
            let mut s = exact_builder.build().unwrap();
            std::hint::black_box(simulate(&traces, &cfg, s.as_mut()));
        },
        3,
        0.2,
    );
    report("simulate 2000 ticks, exact argmax", &m_exact);
    let m_lazy = measure(
        || {
            let mut s = lazy_builder.build().unwrap();
            std::hint::black_box(simulate(&traces, &cfg, s.as_mut()));
        },
        3,
        0.2,
    );
    report("simulate 2000 ticks, lazy scheduler", &m_lazy);
    println!(
        "lazy speedup: {:.1}x   (ticks/s: exact {:.0}, lazy {:.0})",
        m_exact.mean_s / m_lazy.mean_s,
        2000.0 / m_exact.mean_s,
        2000.0 / m_lazy.mean_s
    );
    json.lane(
        &format!("sched_exact_m{m}"),
        &[("seconds_per_rep", m_exact.mean_s), ("ticks_per_s", 2000.0 / m_exact.mean_s)],
    );
    json.lane(
        &format!("sched_lazy_m{m}"),
        &[("seconds_per_rep", m_lazy.mean_s), ("ticks_per_s", 2000.0 / m_lazy.mean_s)],
    );
    // eval-count diagnostic
    let mut s = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &inst.pages);
    simulate(&traces, &cfg, &mut s);
    println!(
        "lazy evals/tick: {:.1} (exact would be {})",
        s.evals as f64 / s.ticks as f64,
        inst.pages.len()
    );
    json.lane(
        &format!("sched_lazy_m{m}_evals"),
        &[("evals_per_tick", s.evals as f64 / s.ticks as f64)],
    );
}

/// Dynamic-world lanes: the lazy scheduler's select+advance cost under
/// steady churn, against the static-world cost at the same scale. Four
/// lanes per m: the static engine (`simulate_with`), the scenario
/// engine on an EMPTY timeline (isolates the merge-loop overhead), and
/// steady churn at ρ ∈ {0.1%, 1%} of pages per unit time (retire +
/// birth pairs, worst-case slot recycling). The acceptance bar is the
/// `scenario_churn_overhead` lane: churn at ρ = 1% within 2× of the
/// static-world lane. Trace generation is pre-pass (untimed) in every
/// lane; world-event stream regeneration is necessarily in-loop — it
/// IS the cost being measured.
fn bench_scenario_churn(json: &mut BenchJson, smoke: bool) {
    let m: usize = if smoke { 2_048 } else { 100_000 };
    let horizon = 10.0;
    let r = if smoke { 200.0 } else { 2_000.0 };
    println!("\n-- scenario_churn: lazy select+advance, static vs dynamic world (m={m}) --");
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(23);
    let inst = spec.gen_instance(&mut rng).normalized();
    let mut trng = Rng::new(24);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(r, horizon).unwrap();
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);

    // Every lane constructs its scheduler INSIDE the timed closure
    // (the bench_schedulers idiom): a reused scheduler would pay the
    // world-mutated rebuild only in the churn lanes, biasing the
    // overhead ratio with cost that is not churn. Fresh construction
    // is a symmetric offset in numerator and denominator.

    // static-world baseline: the plain streaming engine
    let secs_static = {
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_with(&mut ws, &traces, &cfg, sched.as_mut()));
            },
            3,
            0.2,
        );
        report(&format!("static engine        m={m}"), &meas);
        json.lane(
            &format!("scenario_static_m{m}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        meas.mean_s
    };

    // scenario engine, empty timeline: merge-loop overhead only
    {
        let sc = Scenario::new(inst.pages.clone(), 25);
        let mut ws = ScenarioWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_scenario_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    &sc,
                    sched.as_mut(),
                ));
            },
            3,
            0.2,
        );
        report(&format!("scenario empty       m={m}"), &meas);
        json.lane(
            &format!("scenario_empty_m{m}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
    }

    // steady churn: ρ · m page turnovers per unit time
    let mut churn_1pct = f64::NAN;
    for (label, rho) in [("rho0_1pct", 0.001), ("rho1pct", 0.01)] {
        let mut sc = Scenario::new(inst.pages.clone(), 25);
        add_steady_churn(&mut sc, rho, horizon, &BornPageSpec::default(), 26);
        let events = sc.events().len();
        let mut ws = ScenarioWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_scenario_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    &sc,
                    sched.as_mut(),
                ));
            },
            3,
            0.2,
        );
        report(&format!("churn rho={rho:<7} m={m}"), &meas);
        println!("{:>46} {events} world events/rep", "");
        json.lane(
            &format!("scenario_churn_m{m}_{label}"),
            &[
                ("seconds_per_rep", meas.mean_s),
                ("ticks_per_s", r * horizon / meas.mean_s),
                ("world_events", events as f64),
            ],
        );
        if rho == 0.01 {
            churn_1pct = meas.mean_s;
        }
    }
    let overhead = churn_1pct / secs_static.max(1e-12);
    println!("churn(1%)/static overhead: {overhead:.2}x (acceptance: <= 2x)");
    json.lane(&format!("scenario_churn_overhead_m{m}"), &[("x", overhead)]);
}

fn bench_end_to_end(json: &mut BenchJson, smoke: bool) {
    let m = if smoke { 200 } else { 1000 };
    println!("\n-- end-to-end simulation throughput (m={m}, R=100, T=100) --");
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(5);
    let inst = spec.gen_instance(&mut rng).normalized();
    let mut trng = Rng::new(6);
    let traces = generate_traces(&inst.pages, 100.0, CisDelay::None, &mut trng);
    let (c, s_, r_) = traces.counts();
    let events = (c + s_ + r_) as f64;
    let cfg = SimConfig::new(100.0, 100.0).unwrap();
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);
    let m = measure(
        || {
            let mut s = builder.build().unwrap();
            std::hint::black_box(simulate(&traces, &cfg, s.as_mut()));
        },
        3,
        0.3,
    );
    report("lazy GREEDY-NCIS full rep (10k ticks)", &m);
    println!("{:>46} {:.0}k ticks/s", "", 10.0 / m.mean_s);
    json.lane(
        "sim_e2e_lazy_m1000",
        &[
            ("seconds_per_rep", m.mean_s),
            ("ticks_per_s", 10_000.0 / m.mean_s),
            ("events_per_s", events / m.mean_s),
        ],
    );
}

/// The pre-change `run_cell`, verbatim: instance generation, baseline
/// solve, merged-sort `simulate_reference`, serial repetitions, and the
/// same per-rep accuracy/rate accumulation — so the timed work is
/// symmetric with the `run_cell_with_threads` lane and the recorded
/// speedup isolates engine + driver, not measurement scope. Returns
/// (mean accuracy, wall seconds).
fn run_cell_reference(spec: &ExperimentSpec, put: PolicyUnderTest) -> (f64, f64) {
    let t0 = Instant::now();
    let mut irng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut irng).normalized();
    let baseline = ncis_crawl::solver::baseline_accuracy(&inst).unwrap_or(f64::NAN);
    std::hint::black_box(baseline);
    let mut acc = RepAccumulator::new(inst.pages.len());
    for rep in 0..spec.reps {
        let mut trng = Rng::new(spec.seed ^ (0xC0FFEE + rep as u64));
        let traces = generate_traces(&inst.pages, spec.horizon, spec.delay, &mut trng);
        let mut cfg = SimConfig::new(spec.bandwidth, spec.horizon).unwrap();
        cfg.cis_discard_window = spec.discard_window;
        let mut sched = make_scheduler(put, &inst, &[]);
        let res = simulate_reference(&traces, &cfg, sched.as_mut());
        acc.push(res.accuracy, &res.empirical_rates(spec.horizon));
    }
    (acc.accuracy().mean, t0.elapsed().as_secs_f64())
}

fn bench_cell_engines(json: &mut BenchJson, smoke: bool) {
    let (def_m, def_t, def_reps) = if smoke { (128, 60, 2) } else { (1000, 1000, 8) };
    let m = env_usize("NCIS_PERF_M", def_m);
    let horizon = env_usize("NCIS_PERF_T", def_t) as f64;
    let reps = env_usize("NCIS_PERF_REPS", def_reps);
    let threads = default_rep_threads();
    println!(
        "\n-- experiment cell: serial merged-sort engine vs parallel streaming \
         (m={m}, R=100, T={horizon}, reps={reps}, {threads} threads) --"
    );
    // pinned Materialized: this lane's meaning is "engine + driver,
    // same realization as the serial merged-sort reference" — the
    // streamed generation path has its own lanes (bench_event_sourcing)
    let spec = ExperimentSpec {
        horizon,
        ..ExperimentSpec::section6(m, reps)
    }
    .with_partial_cis()
    .with_false_positives()
    .with_trace_mode(TraceMode::Materialized);
    // total events processed per engine pass (untimed pre-pass, same seeds)
    let mut irng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut irng).normalized();
    let mut events = 0f64;
    for rep in 0..spec.reps {
        let mut trng = Rng::new(spec.seed ^ (0xC0FFEE + rep as u64));
        let traces = generate_traces(&inst.pages, spec.horizon, spec.delay, &mut trng);
        let (c, s, r) = traces.counts();
        events += (c + s + r) as f64;
    }
    let ticks = spec.bandwidth * spec.horizon * spec.reps as f64;

    for (label, put) in [
        ("greedy", PolicyUnderTest::Greedy(PolicyKind::Greedy)),
        ("lazy_ncis", PolicyUnderTest::Lazy(PolicyKind::GreedyNcis)),
    ] {
        let (acc_ref, sec_ref) = run_cell_reference(&spec, put);
        let t0 = Instant::now();
        let cell = run_cell_with_threads(&spec, put, threads);
        let sec_new = t0.elapsed().as_secs_f64();
        let speedup = sec_ref / sec_new.max(1e-12);
        println!(
            "{:<12} reference serial {sec_ref:8.2}s | streaming parallel {sec_new:8.2}s \
             | speedup {speedup:5.2}x (accuracy {acc_ref:.4} vs {:.4})",
            put.name(),
            cell.mean
        );
        json.lane(
            &format!("cell_{label}_serial_reference"),
            &[
                ("seconds", sec_ref),
                ("reps", spec.reps as f64),
                ("m", m as f64),
                ("horizon", spec.horizon),
                ("bandwidth", spec.bandwidth),
                ("ticks_per_s", ticks / sec_ref),
                ("events_per_s", events / sec_ref),
                ("accuracy_mean", acc_ref),
            ],
        );
        json.lane(
            &format!("cell_{label}_parallel_streaming"),
            &[
                ("seconds", sec_new),
                ("reps", spec.reps as f64),
                ("m", m as f64),
                ("horizon", spec.horizon),
                ("bandwidth", spec.bandwidth),
                ("threads", threads as f64),
                ("ticks_per_s", ticks / sec_new),
                ("events_per_s", events / sec_new),
                ("accuracy_mean", cell.mean),
            ],
        );
        json.lane(&format!("cell_{label}_speedup"), &[("x", speedup)]);
    }
}

/// Event-sourcing lanes (the zero-materialization acceptance bars):
///
/// - `gen_{materialized,streamed}_m*`: full-horizon event generation —
///   `generate_traces` (stores every event) vs `StreamedSource`
///   construction + a full drain (stores nothing). Peak memory is the
///   counting allocator's live-bytes high-water over the lane;
///   acceptance: streamed ≤ 10% of materialized at the largest m.
/// - `sim_{materialized,streamed}_m*`: end-to-end repetition
///   throughput under the lazy GREEDY-NCIS scheduler. The materialized
///   lane replays pre-built traces (generation untimed — the best case
///   for the old path); the streamed lane pays generation in-loop.
///   Acceptance: streamed/materialized ≤ 1.2× at m=1e5.
/// - `sim_streamed_m<big>`: the lane the old path cannot run at scale —
///   one full streamed repetition at the largest population.
///
/// Returns the lane names it declared (the required-lane self-check in
/// `main` fails the job if any is missing from BENCH_perf.json).
fn bench_event_sourcing(json: &mut BenchJson, smoke: bool) -> Vec<String> {
    let mut declared: Vec<String> = Vec::new();
    let gen_ms: Vec<usize> = if smoke {
        vec![512]
    } else {
        vec![env_usize("NCIS_GEN_M_SMALL", 100_000), env_usize("NCIS_GEN_M", 1_000_000)]
    };
    let horizon = if smoke { 20.0 } else { env_usize("NCIS_GEN_T", 100) as f64 };
    println!("\n-- event sourcing: materialized vs streamed generation (T={horizon}) --");
    if !smoke {
        println!(
            "(the materialized lane at m=1e6, T=100 allocates ~1.5 GB; \
             scale with NCIS_GEN_M / NCIS_GEN_T)"
        );
    }
    for &m in &gen_ms {
        let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
        let mut irng = Rng::new(31);
        let inst = spec.gen_instance(&mut irng).normalized();

        // materialized: every event realized and stored
        let (mat_peak, mat_events, mat_secs) = {
            let span = MemSpan::begin();
            let mut trng = Rng::new(32);
            let t0 = Instant::now();
            let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
            let secs = t0.elapsed().as_secs_f64();
            let peak = span.peak_delta();
            let (c, s, r) = traces.counts();
            (peak, (c + s + r) as f64, secs)
        };
        let lane = format!("gen_materialized_m{m}");
        println!(
            "{lane:<34} {mat_secs:8.3}s  {:9.1}k ev  peak {:8.1} MB ({:.0} B/page)",
            mat_events / 1e3,
            mat_peak as f64 / 1e6,
            mat_peak as f64 / m as f64
        );
        json.lane(
            &lane,
            &[
                ("seconds", mat_secs),
                ("events", mat_events),
                ("events_per_s", mat_events / mat_secs.max(1e-12)),
                ("peak_bytes", mat_peak as f64),
                ("bytes_per_page", mat_peak as f64 / m as f64),
            ],
        );
        declared.push(lane);

        // streamed: same master seed (seed-paired at the per-page
        // level), construct the sources and drain every event without
        // storing any
        let (st_peak, st_events, st_secs, st_allocs) = {
            let span = MemSpan::begin();
            let mut trng = Rng::new(32);
            let t0 = Instant::now();
            let mut src =
                StreamedSource::new(&inst.pages, horizon, CisDelay::None, &mut trng)
                    .expect("valid delay");
            let mut n = 0u64;
            for i in 0..src.len() {
                let mut ev = src.first(i);
                while let Some((_, k)) = ev {
                    n += 1;
                    ev = src.advance(i, k);
                }
            }
            let secs = t0.elapsed().as_secs_f64();
            (span.peak_delta(), n as f64, secs, span.allocs())
        };
        let lane = format!("gen_streamed_m{m}");
        println!(
            "{lane:<34} {st_secs:8.3}s  {:9.1}k ev  peak {:8.1} MB ({:.0} B/page, {} allocs)",
            st_events / 1e3,
            st_peak as f64 / 1e6,
            st_peak as f64 / m as f64,
            st_allocs
        );
        json.lane(
            &lane,
            &[
                ("seconds", st_secs),
                ("events", st_events),
                ("events_per_s", st_events / st_secs.max(1e-12)),
                ("peak_bytes", st_peak as f64),
                ("bytes_per_page", st_peak as f64 / m as f64),
                ("allocs", st_allocs as f64),
            ],
        );
        declared.push(lane);

        let ratio = st_peak as f64 / (mat_peak as f64).max(1.0);
        println!(
            "streamed/materialized peak memory at m={m}: {:.1}% (acceptance at largest m: <= 10%)",
            ratio * 100.0
        );
        let lane = format!("gen_mem_ratio_m{m}");
        json.lane(&lane, &[("streamed_over_materialized", ratio)]);
        declared.push(lane);
    }
    if let Some(rss) = mem::peak_rss_bytes() {
        json.lane("gen_peak_rss", &[("process_vmhwm_bytes", rss as f64)]);
    }

    // --- simulation throughput: replay vs streamed, lazy GREEDY-NCIS ---
    let m_sim: usize = if smoke { 512 } else { 100_000 };
    let sim_horizon = 10.0;
    let r = if smoke { 200.0 } else { 2_000.0 };
    println!("\n-- event sourcing: simulation throughput, replay vs streamed (m={m_sim}) --");
    let spec = ExperimentSpec::section6(m_sim, 1).with_partial_cis().with_false_positives();
    let mut irng = Rng::new(33);
    let inst = spec.gen_instance(&mut irng).normalized();
    let cfg = SimConfig::new(r, sim_horizon).expect("valid bench bandwidth");
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);

    // materialized lane: generation is an untimed pre-pass — the most
    // favourable accounting for the old path
    let secs_mat = {
        let mut trng = Rng::new(34);
        let traces = generate_traces(&inst.pages, sim_horizon, CisDelay::None, &mut trng);
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_with(&mut ws, &traces, &cfg, sched.as_mut()));
            },
            3,
            0.2,
        );
        report(&format!("replay engine        m={m_sim}"), &meas);
        json.lane(
            &format!("sim_materialized_m{m_sim}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * sim_horizon / meas.mean_s)],
        );
        meas.mean_s
    };
    declared.push(format!("sim_materialized_m{m_sim}"));

    // streamed lane: source construction (the generation work) is paid
    // inside the timed repetition, as it is in a real streamed cell
    let secs_st = {
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut trng = Rng::new(34);
                let src =
                    StreamedSource::new(&inst.pages, sim_horizon, CisDelay::None, &mut trng)
                        .expect("valid delay");
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_streamed_with(&mut ws, src, &cfg, sched.as_mut()));
            },
            3,
            0.2,
        );
        report(&format!("streamed engine      m={m_sim}"), &meas);
        json.lane(
            &format!("sim_streamed_m{m_sim}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * sim_horizon / meas.mean_s)],
        );
        meas.mean_s
    };
    declared.push(format!("sim_streamed_m{m_sim}"));
    let overhead = secs_st / secs_mat.max(1e-12);
    println!("streamed/replay throughput overhead: {overhead:.2}x (acceptance: <= 1.2x)");
    let lane = format!("sim_mode_ratio_m{m_sim}");
    json.lane(&lane, &[("streamed_over_materialized", overhead)]);
    declared.push(lane);

    // the lane the materialized path cannot run at scale: one full
    // streamed repetition at the largest population (O(m) memory)
    let m_big: usize = if smoke { 1_024 } else { env_usize("NCIS_GEN_M", 1_000_000) };
    let big_horizon = 2.0;
    let big_r = if smoke { 200.0 } else { 1_000.0 };
    println!("\n-- event sourcing: streamed repetition at m={m_big} --");
    let spec = ExperimentSpec::section6(m_big, 1).with_partial_cis().with_false_positives();
    let mut irng = Rng::new(35);
    let inst = spec.gen_instance(&mut irng).normalized();
    let cfg = SimConfig::new(big_r, big_horizon).expect("valid bench bandwidth");
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);
    let span = MemSpan::begin();
    let mut ws = SimWorkspace::new();
    let meas = measure(
        || {
            let mut trng = Rng::new(36);
            let src = StreamedSource::new(&inst.pages, big_horizon, CisDelay::None, &mut trng)
                .expect("valid delay");
            let mut sched = builder.build().unwrap();
            std::hint::black_box(simulate_streamed_with(&mut ws, src, &cfg, sched.as_mut()));
        },
        3,
        0.2,
    );
    report(&format!("streamed rep        m={m_big}"), &meas);
    let lane = format!("sim_streamed_m{m_big}");
    json.lane(
        &lane,
        &[
            ("seconds_per_rep", meas.mean_s),
            ("ticks_per_s", big_r * big_horizon / meas.mean_s),
            ("peak_bytes", span.peak_delta() as f64),
        ],
    );
    declared.push(lane);
    declared
}

/// Fault-layer lanes (the fault-injection acceptance bars):
///
/// - `fault_overhead_m*`: the fault engine with the inert
///   (zero-fault) model vs the plain engine on the same traces and
///   scheduler — measures the cost of carrying the outcome/retry
///   machinery when it is disabled. Acceptance: ≤ 1.05× at m=1e5.
/// - `fault_degraded_m*`: the same cell under a heavy fault mix
///   (transient + timeout + correlated outages, exponential-backoff
///   retries) — the wasted-bandwidth fraction and throughput of the
///   degraded mode, recorded for trajectory rather than gated.
///
/// Returns the declared acceptance lane names.
fn bench_faults(json: &mut BenchJson, smoke: bool) -> Vec<String> {
    use ncis_crawl::fault::{
        simulate_faulty_with, FaultConfig, FaultModel, RetryPolicy,
    };
    let mut declared = Vec::new();
    let m: usize = if smoke { 2_048 } else { 100_000 };
    let horizon = 10.0;
    let r = if smoke { 200.0 } else { 2_000.0 };
    println!("\n-- fault layer: inert-model overhead and degraded mode (m={m}) --");
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let mut irng = Rng::new(41);
    let inst = spec.gen_instance(&mut irng).normalized();
    let mut trng = Rng::new(42);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(r, horizon).expect("valid bench bandwidth");
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);

    // plain engine baseline (same construction idiom as the other lanes)
    let secs_plain = {
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_with(&mut ws, &traces, &cfg, sched.as_mut()));
            },
            3,
            0.2,
        );
        report(&format!("plain engine         m={m}"), &meas);
        json.lane(
            &format!("fault_baseline_m{m}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        meas.mean_s
    };

    // fault engine, zero-fault model: the overhead acceptance lane
    let secs_inert = {
        let mut ws = SimWorkspace::new();
        let mut model = FaultModel::inert();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_faulty_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    sched.as_mut(),
                    &mut model,
                    RetryPolicy::default(),
                ));
            },
            3,
            0.2,
        );
        report(&format!("fault engine (inert) m={m}"), &meas);
        json.lane(
            &format!("fault_inert_m{m}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        meas.mean_s
    };
    let overhead = secs_inert / secs_plain.max(1e-12);
    println!("fault-disabled overhead: {overhead:.3}x (acceptance: <= 1.05x)");
    let lane = format!("fault_overhead_m{m}");
    json.lane(&lane, &[("x", overhead)]);
    declared.push(lane);

    // degraded mode: heavy fault mix with backoff retries
    {
        let mut fault_cfg = FaultConfig {
            transient_prob: 0.2,
            timeout_prob: 0.05,
            gone_prob: 0.001,
            hosts: 50,
            outages: Vec::new(),
            seed: 43,
        };
        fault_cfg.add_correlated_outages(20, horizon / 20.0, horizon, 44);
        let mut model = FaultModel::new(fault_cfg).expect("valid bench fault config");
        let mut ws = SimWorkspace::new();
        let mut wasted = 0.0;
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                let res = simulate_faulty_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    sched.as_mut(),
                    &mut model,
                    RetryPolicy::default(),
                );
                wasted = res.faults.wasted_fraction();
                std::hint::black_box(res);
            },
            3,
            0.2,
        );
        report(&format!("fault engine (heavy) m={m}"), &meas);
        println!("{:>46} wasted-bandwidth fraction {wasted:.3}", "");
        json.lane(
            &format!("fault_degraded_m{m}"),
            &[
                ("seconds_per_rep", meas.mean_s),
                ("ticks_per_s", r * horizon / meas.mean_s),
                ("wasted_fraction", wasted),
            ],
        );
    }
    declared
}

/// Serving-layer lanes (the request-side acceptance bars):
///
/// - `serve_off_m*` / `serve_overhead_m*`: the served engine carrying a
///   [`RequestTraffic::off`] session vs the plain engine on the same
///   traces and scheduler — the cost of the serve branch in the event
///   loop when no request ever arrives. Acceptance: ≤ 1.10× at m=1e5.
/// - `serve_on_m*`: the same cell under a loaded Zipf request stream
///   (diurnal + one flash crowd) — throughput of answering requests
///   from the freshness cache, recorded for trajectory rather than
///   gated.
///
/// Returns the declared acceptance lane names.
fn bench_serving(json: &mut BenchJson, smoke: bool) -> Vec<String> {
    use ncis_crawl::serving::{RequestTraffic, ServingSession};
    use ncis_crawl::sim::simulate_served_with;
    let mut declared = Vec::new();
    let m: usize = if smoke { 2_048 } else { 100_000 };
    let horizon = 10.0;
    let r = if smoke { 200.0 } else { 2_000.0 };
    println!("\n-- serving layer: zero-traffic overhead and loaded serving (m={m}) --");
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let mut irng = Rng::new(45);
    let inst = spec.gen_instance(&mut irng).normalized();
    let mut trng = Rng::new(46);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(r, horizon).expect("valid bench bandwidth");
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);

    // plain engine baseline (same construction idiom as the other lanes)
    let secs_plain = {
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_with(&mut ws, &traces, &cfg, sched.as_mut()));
            },
            3,
            0.2,
        );
        report(&format!("plain engine         m={m}"), &meas);
        json.lane(
            &format!("serve_baseline_m{m}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        meas.mean_s
    };

    // served engine, off traffic: the overhead acceptance lane
    let secs_off = {
        let off = RequestTraffic::off();
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                let mut serving = ServingSession::new(&off, &inst.pages, horizon);
                std::hint::black_box(simulate_served_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    sched.as_mut(),
                    &mut serving,
                ));
            },
            3,
            0.2,
        );
        report(&format!("served engine (off)  m={m}"), &meas);
        let lane = format!("serve_off_m{m}");
        json.lane(
            &lane,
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        declared.push(lane);
        meas.mean_s
    };
    let overhead = secs_off / secs_plain.max(1e-12);
    println!("serving-disabled overhead: {overhead:.3}x (acceptance: <= 1.10x)");
    let lane = format!("serve_overhead_m{m}");
    json.lane(&lane, &[("x", overhead)]);
    declared.push(lane);

    // loaded serving: Zipf requests at the crawl bandwidth, diurnal
    // cycle, one mid-run flash crowd
    {
        let traffic = RequestTraffic::new(r, 1.1, 47)
            .expect("valid bench traffic")
            .with_diurnal(horizon / 4.0, 0.5)
            .expect("valid diurnal cycle")
            .with_flash(horizon * 0.3, horizon * 0.1, m / 2, 2.0 * r)
            .expect("valid flash crowd");
        let mut ws = SimWorkspace::new();
        let mut served = 0u64;
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                let mut serving = ServingSession::new(&traffic, &inst.pages, horizon);
                let res =
                    simulate_served_with(&mut ws, &traces, &cfg, sched.as_mut(), &mut serving);
                served = serving.metrics().served;
                std::hint::black_box((res, serving.into_metrics()));
            },
            3,
            0.2,
        );
        report(&format!("served engine (on)   m={m}"), &meas);
        println!("{:>46} requests served {served}", "");
        let lane = format!("serve_on_m{m}");
        json.lane(
            &lane,
            &[
                ("seconds_per_rep", meas.mean_s),
                ("serves_per_s", served as f64 / meas.mean_s),
                ("served", served as f64),
            ],
        );
        declared.push(lane);
    }
    declared
}

/// Estimation-loop lanes (the learned-knowledge acceptance bars):
///
/// - `est_oracle_m*`: the plain oracle-knowledge scheduler — the
///   baseline everything learned is compared against.
/// - `est_learned_m*`: the same cell under `Knowledge::Learned` — the
///   full estimation loop in the hot path (per-fetch observation,
///   budgeted re-projection through `on_params_changed`).
/// - `est_overhead_m*`: learned/oracle wall-clock ratio.
///   Acceptance: ≤ 1.25× at m=1e5.
///
/// Returns the declared acceptance lane names.
fn bench_estimation(json: &mut BenchJson, smoke: bool) -> Vec<String> {
    use ncis_crawl::{EstimatorConfig, Knowledge};
    let mut declared = Vec::new();
    let m: usize = if smoke { 2_048 } else { 100_000 };
    let horizon = 10.0;
    let r = if smoke { 200.0 } else { 2_000.0 };
    println!("\n-- estimation loop: oracle vs learned knowledge (m={m}) --");
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let mut irng = Rng::new(51);
    let inst = spec.gen_instance(&mut irng).normalized();
    let mut trng = Rng::new(52);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(r, horizon).expect("valid bench bandwidth");
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);

    let mut lane_secs = [0.0f64; 2];
    for (slot, (label, knowledge)) in [
        ("oracle", Knowledge::Oracle),
        ("learned", Knowledge::Learned(EstimatorConfig::default())),
    ]
    .into_iter()
    .enumerate()
    {
        let lane_builder = builder.clone().knowledge(knowledge);
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut sched = lane_builder.build().unwrap();
                std::hint::black_box(simulate_with(&mut ws, &traces, &cfg, sched.as_mut()));
            },
            3,
            0.2,
        );
        report(&format!("{label:>8} knowledge  m={m}"), &meas);
        let lane = format!("est_{label}_m{m}");
        json.lane(
            &lane,
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        declared.push(lane);
        lane_secs[slot] = meas.mean_s;
    }
    let overhead = lane_secs[1] / lane_secs[0].max(1e-12);
    println!("learned-knowledge overhead: {overhead:.3}x (acceptance: <= 1.25x)");
    let lane = format!("est_overhead_m{m}");
    json.lane(&lane, &[("x", overhead)]);
    declared.push(lane);
    declared
}

/// Flight-recorder lanes (the tracing acceptance bars):
///
/// - `trace_off_m*` / `trace_overhead_m*`: the traced engine entry with
///   `tr = None` vs the plain engine on the same traces and scheduler —
///   the cost of carrying the Option-gated trace branches when nothing
///   records. Acceptance: ≤ 1.02× at m=1e5.
/// - `trace_on_m*`: the same cell with a recording ring-buffer handle
///   attached to engine and scheduler — full event emission into the
///   bounded flight recorder. Acceptance: ≤ 1.25× at m=1e5.
///
/// Returns the declared acceptance lane names.
fn bench_trace(json: &mut BenchJson, smoke: bool) -> Vec<String> {
    use ncis_crawl::sim::simulate_traced_with;
    use ncis_crawl::trace::TraceHandle;
    let mut declared = Vec::new();
    let m: usize = if smoke { 2_048 } else { 100_000 };
    let horizon = 10.0;
    let r = if smoke { 200.0 } else { 2_000.0 };
    println!("\n-- flight recorder: disabled-path and recording overhead (m={m}) --");
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let mut irng = Rng::new(61);
    let inst = spec.gen_instance(&mut irng).normalized();
    let mut trng = Rng::new(62);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(r, horizon).expect("valid bench bandwidth");
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);

    // plain engine baseline (same construction idiom as the other lanes)
    let secs_plain = {
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_with(&mut ws, &traces, &cfg, sched.as_mut()));
            },
            3,
            0.2,
        );
        report(&format!("plain engine         m={m}"), &meas);
        json.lane(
            &format!("trace_baseline_m{m}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        meas.mean_s
    };

    // traced entry, no handle: the disabled-path acceptance lane
    let secs_off = {
        let mut ws = SimWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_traced_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    sched.as_mut(),
                    None,
                    None,
                ));
            },
            3,
            0.2,
        );
        report(&format!("traced engine (off)  m={m}"), &meas);
        let lane = format!("trace_off_m{m}");
        json.lane(
            &lane,
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        declared.push(lane);
        meas.mean_s
    };
    let overhead = secs_off / secs_plain.max(1e-12);
    println!("trace-disabled overhead: {overhead:.3}x (acceptance: <= 1.02x)");
    let lane = format!("trace_overhead_m{m}");
    json.lane(&lane, &[("x", overhead)]);
    declared.push(lane);

    // recording: engine + scheduler emit into a bounded ring (the cap
    // keeps memory flat however long the run — overwrites are counted,
    // not allocated)
    {
        let mut ws = SimWorkspace::new();
        let mut events = 0u64;
        let meas = measure(
            || {
                let handle = TraceHandle::recorder(1 << 16);
                let mut sched =
                    builder.clone().with_trace(handle.clone()).build().unwrap();
                let res = simulate_traced_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    sched.as_mut(),
                    None,
                    Some(&handle),
                );
                events = handle
                    .recorder_arc()
                    .map(|rec| {
                        let rec = rec
                            .lock()
                            .unwrap_or_else(std::sync::PoisonError::into_inner);
                        rec.len() as u64 + rec.dropped()
                    })
                    .unwrap_or(0);
                std::hint::black_box(res);
            },
            3,
            0.2,
        );
        report(&format!("traced engine (on)   m={m}"), &meas);
        let rec_overhead = meas.mean_s / secs_plain.max(1e-12);
        println!(
            "{:>46} events recorded {events} ({rec_overhead:.3}x, acceptance: <= 1.25x)",
            ""
        );
        let lane = format!("trace_on_m{m}");
        json.lane(
            &lane,
            &[
                ("seconds_per_rep", meas.mean_s),
                ("events_per_s", events as f64 / meas.mean_s),
                ("x", rec_overhead),
            ],
        );
        declared.push(lane);
    }
    declared
}

/// Scenario-DSL lanes (the adversarial-world acceptance bars):
///
/// - `world_parse_m*`: parse + compile of a DSL world text at the
///   acceptance population — the whole `parse_world` path including
///   §6.3 instance generation and normalization, measured per pass.
/// - `world_overhead_m*`: the scenario engine replaying the
///   DSL-compiled world vs the hand-constructed twin it is asserted
///   bit-identical to, on the same traces and scheduler. The compiled
///   world is plain `Scenario` data, so the lane pins the claim that
///   authoring a world in the DSL costs nothing at run time.
///   Acceptance: ≤ 1.05× at m=1e5.
/// - `fuzz_rep_rate`: sustained worlds/s of the replay fuzzer (each
///   world = parse, round-trip, audit, and every engine lane run
///   twice), recorded for trajectory so CI's time-boxed `fuzz-smoke`
///   budget stays calibrated.
///
/// Returns the declared acceptance lane names.
fn bench_world_dsl(json: &mut BenchJson, smoke: bool) -> Vec<String> {
    use ncis_crawl::scenario::fuzz::{run_fuzz, FuzzConfig};
    use ncis_crawl::scenario::{bit_identical, PageSet};
    use ncis_crawl::{parse_world, WorldEvent};
    let mut declared = Vec::new();
    let m: usize = if smoke { 2_048 } else { 100_000 };
    let horizon = 10.0;
    let r = if smoke { 200.0 } else { 2_000.0 };
    println!("\n-- scenario DSL: parse+compile, DSL vs hand-built replay (m={m}) --");
    let text = format!(
        "world horizon={horizon:?} bandwidth={r:?} scenario_seed=0x5ce7\n\
         pages section6 m={m} seed=0x5eed partial_cis false_positives normalized\n\
         churn rho=0.001 seed=0x5ce8\n\
         outage t=5.0 duration=2.0 pages=all\n"
    );

    // parse + compile throughput (compile dominates: it realizes the
    // §6.3 population)
    let meas = measure(
        || {
            std::hint::black_box(parse_world(&text).unwrap());
        },
        3,
        0.2,
    );
    report(&format!("parse+compile        m={m}"), &meas);
    println!("{:>46} {:.1}k pages/s", "", m as f64 / meas.mean_s / 1e3);
    let lane = format!("world_parse_m{m}");
    json.lane(
        &lane,
        &[("seconds_per_parse", meas.mean_s), ("pages_per_s", m as f64 / meas.mean_s)],
    );
    declared.push(lane);

    // the hand-constructed twin of the same world, and the identity
    // check the overhead ratio rests on
    let world = parse_world(&text).expect("bench world parses");
    let spec = ExperimentSpec::section6(m, 1).with_partial_cis().with_false_positives();
    let mut irng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut irng).normalized();
    let mut hand = Scenario::new(inst.pages.clone(), 0x5CE7);
    add_steady_churn(&mut hand, 0.001, horizon, &BornPageSpec::default(), 0x5CE8);
    hand.push(5.0, WorldEvent::CisOutage { pages: PageSet::All, duration: 2.0 });
    assert!(
        bit_identical(&world.scenario, &hand),
        "DSL world drifted from its hand-built twin; the overhead ratio is meaningless"
    );

    let mut trng = Rng::new(71);
    let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(r, horizon).expect("valid bench bandwidth");
    let builder = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&inst.pages);
    let mut lane_secs = [0.0f64; 2];
    for (slot, (label, sc)) in
        [("hand", &hand), ("dsl", &world.scenario)].into_iter().enumerate()
    {
        let mut ws = ScenarioWorkspace::new();
        let meas = measure(
            || {
                let mut sched = builder.build().unwrap();
                std::hint::black_box(simulate_scenario_with(
                    &mut ws,
                    &traces,
                    &cfg,
                    sc,
                    sched.as_mut(),
                ));
            },
            3,
            0.2,
        );
        report(&format!("{label:>8} world      m={m}"), &meas);
        json.lane(
            &format!("world_{label}_m{m}"),
            &[("seconds_per_rep", meas.mean_s), ("ticks_per_s", r * horizon / meas.mean_s)],
        );
        lane_secs[slot] = meas.mean_s;
    }
    let overhead = lane_secs[1] / lane_secs[0].max(1e-12);
    println!("DSL-world overhead: {overhead:.3}x (acceptance: <= 1.05x)");
    let lane = format!("world_overhead_m{m}");
    json.lane(&lane, &[("x", overhead)]);
    declared.push(lane);

    // fuzz campaign rep rate: one deterministic timed campaign
    let worlds = if smoke { 6 } else { 24 };
    println!("\n-- fuzz campaign rep rate ({worlds} worlds) --");
    let t0 = Instant::now();
    let out = run_fuzz(&FuzzConfig { worlds, start_seed: 0x9000, budget: None });
    let secs = t0.elapsed().as_secs_f64();
    println!(
        "fuzz: {} worlds, {} lanes in {secs:.2}s ({:.1} worlds/s, {} violations)",
        out.worlds,
        out.lanes,
        out.worlds as f64 / secs.max(1e-12),
        out.violations.len()
    );
    json.lane(
        "fuzz_rep_rate",
        &[
            ("worlds", out.worlds as f64),
            ("lanes", out.lanes as f64),
            ("seconds", secs),
            ("worlds_per_s", out.worlds as f64 / secs.max(1e-12)),
            ("violations", out.violations.len() as f64),
        ],
    );
    declared.push("fuzz_rep_rate".into());
    declared
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke");
    println!(
        "perf bench (see EXPERIMENTS.md §Perf){}",
        if smoke { " [--smoke: CI-sized lanes]" } else { "" }
    );
    let mut json = BenchJson::new("perf");
    json.lane(
        "meta",
        &[
            ("rep_threads", default_rep_threads() as f64),
            ("smoke", if smoke { 1.0 } else { 0.0 }),
        ],
    );
    bench_value_functions(&mut json);
    bench_batched_values(&mut json);
    bench_select_argmax(&mut json, smoke);
    bench_calendar(&mut json, smoke);
    bench_schedulers(&mut json, smoke);
    bench_scenario_churn(&mut json, smoke);
    bench_end_to_end(&mut json, smoke);
    bench_cell_engines(&mut json, smoke);
    let mut declared = bench_event_sourcing(&mut json, smoke);
    declared.extend(bench_faults(&mut json, smoke));
    declared.extend(bench_serving(&mut json, smoke));
    declared.extend(bench_estimation(&mut json, smoke));
    declared.extend(bench_trace(&mut json, smoke));
    declared.extend(bench_world_dsl(&mut json, smoke));

    // declared-lane manifest: the acceptance-critical lanes every run
    // of this bench must record, in both --smoke and full mode. CI
    // fails the job when BENCH_perf.json is missing any of them.
    for m in if smoke { vec![1024usize] } else { vec![10_000, 100_000] } {
        declared.push(format!("select_speedup_m{m}"));
    }
    declared.push("calendar_speedup".into());
    declared.push(format!("scenario_churn_overhead_m{}", if smoke { 2_048 } else { 100_000 }));
    declared.push("sim_e2e_lazy_m1000".into());
    declared.push("cell_greedy_speedup".into());
    declared.push("cell_lazy_ncis_speedup".into());

    // cargo runs bench binaries with cwd = the package dir (rust/);
    // write to the workspace root so the perf trajectory lives in one
    // stable place across invocation styles
    let out_dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("..");
    match json.finish_in(&out_dir) {
        Ok(path) => println!("\nmachine-readable results -> {}", path.display()),
        Err(e) => eprintln!("could not write BENCH_perf.json: {e}"),
    }
    let missing: Vec<&String> = declared.iter().filter(|l| !json.has_lane(l)).collect();
    if !missing.is_empty() {
        eprintln!("BENCH_perf.json is missing declared lanes: {missing:?}");
        std::process::exit(1);
    }
    println!("declared-lane check: all {} acceptance lanes recorded", declared.len());
}
