//! Cross-language golden test: the rust-native f64 value function must
//! match the Python oracle (`ref.py`) on the vectors `aot.py` wrote to
//! `artifacts/golden_value.csv`. Skips (with a notice) when artifacts
//! have not been built — run `make artifacts` first.

use ncis_crawl::params::PageParams;
use ncis_crawl::policy::value;

struct GoldenRow {
    iota: f64,
    delta: f64,
    mu: f64,
    lam: f64,
    nu: f64,
    terms: u32,
    value: f64,
    psi: f64,
    w: f64,
}

fn load_golden() -> Option<Vec<GoldenRow>> {
    let path = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts/golden_value.csv");
    let text = std::fs::read_to_string(path).ok()?;
    let mut rows = Vec::new();
    for line in text.lines().skip(1) {
        let c: Vec<&str> = line.split(',').collect();
        if c.len() != 9 {
            continue;
        }
        rows.push(GoldenRow {
            iota: c[0].parse().ok()?,
            delta: c[1].parse().ok()?,
            mu: c[2].parse().ok()?,
            lam: c[3].parse().ok()?,
            nu: c[4].parse().ok()?,
            terms: c[5].parse().ok()?,
            value: c[6].parse().ok()?,
            psi: c[7].parse().ok()?,
            w: c[8].parse().ok()?,
        });
    }
    Some(rows)
}

#[test]
fn native_value_matches_python_oracle() {
    let Some(rows) = load_golden() else {
        eprintln!("SKIP: artifacts/golden_value.csv missing (run `make artifacts`)");
        return;
    };
    assert!(rows.len() >= 3 * 256, "unexpectedly few golden rows: {}", rows.len());
    let mut worst: f64 = 0.0;
    for (i, r) in rows.iter().enumerate() {
        let p = PageParams { delta: r.delta, mu: r.mu, lam: r.lam, nu: r.nu };
        let d = p.derive().unwrap();
        let got = value::value_ncis(r.iota, &d, r.terms);
        let scale = r.value.abs().max(1e-9);
        let err = (got - r.value).abs() / scale;
        worst = worst.max(err);
        assert!(
            err < 1e-8,
            "row {i}: V({:.6}; Δ={:.4} μ={:.4} λ={:.4} ν={:.4}, J={}) = {got:.12e}, oracle {:.12e}",
            r.iota, r.delta, r.mu, r.lam, r.nu, r.terms, r.value
        );
    }
    eprintln!("golden value: worst relative error {worst:.3e} over {} rows", rows.len());
}

#[test]
fn native_psi_w_match_python_oracle() {
    let Some(rows) = load_golden() else {
        eprintln!("SKIP: artifacts/golden_value.csv missing (run `make artifacts`)");
        return;
    };
    for (i, r) in rows.iter().enumerate() {
        let p = PageParams { delta: r.delta, mu: r.mu, lam: r.lam, nu: r.nu };
        let d = p.derive().unwrap();
        let (psi, w) = value::psi_w(r.iota, &d, r.terms);
        assert!(
            (psi - r.psi).abs() / r.psi.abs().max(1e-9) < 1e-8,
            "row {i}: psi {psi} vs {}",
            r.psi
        );
        assert!(
            (w - r.w).abs() / r.w.abs().max(1e-9) < 1e-8,
            "row {i}: w {w} vs {}",
            r.w
        );
    }
}
