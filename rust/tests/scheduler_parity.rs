//! Redesign parity suite: the event-driven schedulers must reproduce
//! the pre-redesign (state-slice) implementations bit-for-bit.
//!
//! Before this redesign the engine handed every scheduler the full
//! `&[PageState]` slice; schedulers read `tau_elap`/`n_cis` out of it.
//! Now each scheduler owns that state (a `PageTracker`) and updates it
//! from `on_cis`/`on_crawl` events. This suite pins the equivalence:
//!
//! 1. a faithful port of the pre-redesign exact `GreedyScheduler`
//!    (engine-style state slice + full O(m) `crawl_value` scan) is run
//!    against the new event-driven `GreedyScheduler` — bit-identical
//!    `SimResult`s across policies, discard windows and bandwidth
//!    schedules, through BOTH engines;
//! 2. the `PageTracker` bookkeeping is compared field-by-field against
//!    a hand-rolled slice updated with the pre-redesign engine rules at
//!    every select (the lazy scheduler's only state inputs);
//! 3. LDS through the event API matches the raw `LdsScheduler` stream;
//! 4. `CrawlerBuilder`-constructed schedulers are bit-identical to
//!    hand-constructed ones for every strategy;
//! 5. serial and parallel `run_cell` agree bit-for-bit for the exact,
//!    lazy and LDS lanes (the pre-redesign determinism contract);
//! 6. `Box<dyn CrawlScheduler + Send>` works as a trait object through
//!    the threaded pipeline path.

use ncis_crawl::coordinator::builder::{CrawlerBuilder, Strategy};
use ncis_crawl::coordinator::crawler::{GreedyScheduler, LdsAdapter, ValueBackend};
use ncis_crawl::coordinator::lazy::LazyGreedyScheduler;
use ncis_crawl::coordinator::pipeline::{run_pipeline, PipelineConfig};
use ncis_crawl::figures::common::{run_cell_serial, run_cell_with_threads, ExperimentSpec};
use ncis_crawl::lds::LdsScheduler;
use ncis_crawl::params::{DerivedParams, PageParams};
use ncis_crawl::policy::{PolicyKind, PolicyUnderTest};
use ncis_crawl::rngkit::Rng;
use ncis_crawl::sched::{CrawlScheduler, PageTracker};
use ncis_crawl::sim::engine::BandwidthSchedule;
use ncis_crawl::sim::{
    generate_traces, simulate, simulate_reference, CisDelay, SimConfig, SimResult,
};

fn pages(m: usize, seed: u64) -> Vec<PageParams> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| PageParams {
            delta: rng.range(0.01, 1.0),
            mu: rng.range(0.01, 1.0),
            lam: rng.f64(),
            nu: rng.range(0.0, 0.6),
        })
        .collect()
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}: accuracy");
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.fresh_hits, b.fresh_hits, "{ctx}: fresh_hits");
    assert_eq!(a.crawl_counts, b.crawl_counts, "{ctx}: crawl_counts");
    assert_eq!(a.ticks, b.ticks, "{ctx}: ticks");
    assert_eq!(a.timeline.len(), b.timeline.len(), "{ctx}: timeline length");
    for (k, (x, y)) in a.timeline.iter().zip(&b.timeline).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: timeline[{k}].t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: timeline[{k}].acc");
    }
}

/// Faithful port of the PRE-REDESIGN exact greedy scheduler: the engine
/// used to own a `PageState` slice (`last_crawl`, `n_cis`) that it
/// updated on CIS delivery and crawls, and `GreedyScheduler::select`
/// rescanned it with `PolicyKind::crawl_value` every tick. This port
/// reproduces those update rules verbatim on top of the event hooks.
struct PreRedesignGreedy {
    policy: PolicyKind,
    raw: Vec<PageParams>,
    envs: Vec<DerivedParams>,
    last_crawl: Vec<f64>,
    n_cis: Vec<u32>,
}

impl PreRedesignGreedy {
    fn new(policy: PolicyKind, pages: &[PageParams]) -> Self {
        Self {
            policy,
            raw: pages.to_vec(),
            envs: pages.iter().map(DerivedParams::from_raw).collect(),
            last_crawl: vec![0.0; pages.len()],
            n_cis: vec![0; pages.len()],
        }
    }
}

impl CrawlScheduler for PreRedesignGreedy {
    fn on_start(&mut self, m: usize) {
        self.last_crawl = vec![0.0; m];
        self.n_cis = vec![0; m];
    }

    fn on_cis(&mut self, page: usize, _t: f64) {
        // the engine's old rule: states[i].n_cis.saturating_add(1)
        self.n_cis[page] = self.n_cis[page].saturating_add(1);
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        // the engine's old rule: states[i] = PageState { last_crawl: t, n_cis: 0 }
        self.last_crawl[page] = t;
        self.n_cis[page] = 0;
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        let mut best = f64::NEG_INFINITY;
        let mut arg = None;
        for (i, (d, p)) in self.envs.iter().zip(&self.raw).enumerate() {
            let v = self.policy.crawl_value(p, d, t - self.last_crawl[i], self.n_cis[i]);
            if v > best {
                best = v;
                arg = Some(i);
            }
        }
        arg
    }
}

const ALL_POLICIES: [PolicyKind; 5] = [
    PolicyKind::Greedy,
    PolicyKind::GreedyCis,
    PolicyKind::GreedyNcis,
    PolicyKind::NcisApprox(2),
    PolicyKind::GreedyCisPlus,
];

#[test]
fn event_driven_exact_greedy_reproduces_pre_redesign() {
    for (seed, policy) in ALL_POLICIES.iter().enumerate().map(|(s, p)| (s as u64, *p)) {
        let ps = pages(40, 10 + seed);
        let horizon = 60.0;
        let mut trng = Rng::new(100 + seed);
        let traces = generate_traces(&ps, horizon, CisDelay::None, &mut trng);
        let mut cfg = SimConfig::new(6.0, horizon).unwrap();
        if seed % 2 == 0 {
            cfg.cis_discard_window = Some(0.1);
        }
        cfg.timeline_window = Some(16);
        let mut old = PreRedesignGreedy::new(policy, &ps);
        let mut new = GreedyScheduler::new(policy, &ps, ValueBackend::Native);
        let a = simulate(&traces, &cfg, &mut old);
        let b = simulate(&traces, &cfg, &mut new);
        assert_bit_identical(&a, &b, &format!("{policy:?} streaming"));
        // and through the merged-sort reference engine
        let mut old = PreRedesignGreedy::new(policy, &ps);
        let mut new = GreedyScheduler::new(policy, &ps, ValueBackend::Native);
        let c = simulate_reference(&traces, &cfg, &mut old);
        let d = simulate_reference(&traces, &cfg, &mut new);
        assert_bit_identical(&c, &d, &format!("{policy:?} reference"));
        assert_bit_identical(&a, &c, &format!("{policy:?} cross-engine"));
    }
}

#[test]
fn event_driven_exact_greedy_reproduces_pre_redesign_under_schedule() {
    let ps = pages(30, 42);
    let horizon = 45.0;
    let mut trng = Rng::new(43);
    let traces = generate_traces(&ps, horizon, CisDelay::Exponential { mean: 0.2 }, &mut trng);
    let cfg = SimConfig {
        bandwidth: BandwidthSchedule::new(vec![(0.0, 4.0), (15.0, 9.0), (30.0, 3.0)]).unwrap(),
        horizon,
        cis_discard_window: Some(0.2),
        timeline_window: Some(8),
    };
    let mut old = PreRedesignGreedy::new(PolicyKind::GreedyNcis, &ps);
    let mut new = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
    let a = simulate(&traces, &cfg, &mut old);
    let b = simulate(&traces, &cfg, &mut new);
    assert_bit_identical(&a, &b, "bandwidth schedule");
}

/// Audit scheduler: maintains BOTH a `PageTracker` and a hand-rolled
/// pre-redesign state slice, asserting they agree at every single
/// select. This pins the tracker semantics the lazy scheduler's wake
/// calendar and value evaluations depend on.
struct TrackerAudit {
    tracker: PageTracker,
    last_crawl: Vec<f64>,
    n_cis: Vec<u32>,
    next: usize,
    audits: u64,
}

impl CrawlScheduler for TrackerAudit {
    fn on_start(&mut self, m: usize) {
        self.tracker.reset(m);
        self.last_crawl = vec![0.0; m];
        self.n_cis = vec![0; m];
        self.next = 0;
    }

    fn on_cis(&mut self, page: usize, _t: f64) {
        self.tracker.on_cis(page);
        self.n_cis[page] = self.n_cis[page].saturating_add(1);
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        self.tracker.on_crawl(page, t);
        self.last_crawl[page] = t;
        self.n_cis[page] = 0;
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        for i in 0..self.last_crawl.len() {
            assert_eq!(
                self.tracker.last_crawl(i).to_bits(),
                self.last_crawl[i].to_bits(),
                "page {i}: last_crawl diverged at t={t}"
            );
            assert_eq!(self.tracker.n_cis(i), self.n_cis[i], "page {i}: n_cis diverged at t={t}");
            assert_eq!(
                self.tracker.tau_elap(i, t).to_bits(),
                (t - self.last_crawl[i]).to_bits(),
                "page {i}: tau_elap diverged at t={t}"
            );
            self.audits += 1;
        }
        let i = self.next;
        self.next = (self.next + 1) % self.last_crawl.len();
        Some(i)
    }
}

#[test]
fn page_tracker_matches_pre_redesign_engine_slice() {
    let ps = pages(20, 7);
    let mut trng = Rng::new(8);
    let traces = generate_traces(&ps, 50.0, CisDelay::Exponential { mean: 0.3 }, &mut trng);
    let mut cfg = SimConfig::new(5.0, 50.0).unwrap();
    cfg.cis_discard_window = Some(0.15);
    let mut audit = TrackerAudit {
        tracker: PageTracker::default(),
        last_crawl: vec![],
        n_cis: vec![],
        next: 0,
        audits: 0,
    };
    simulate(&traces, &cfg, &mut audit);
    assert!(audit.audits > 1000, "audit barely ran: {}", audit.audits);
}

#[test]
fn lds_event_api_matches_raw_sequence() {
    let mut rng = Rng::new(11);
    let rates: Vec<f64> = (0..16).map(|_| rng.range(0.1, 3.0)).collect();
    let mut raw = LdsScheduler::new(&rates);
    let mut adapter = LdsAdapter::new(&rates);
    adapter.on_start(rates.len());
    for j in 0..2000 {
        assert_eq!(raw.next(), adapter.select(j as f64 * 0.01), "step {j}");
    }
    // the LDS stream ignores CIS/crawl events entirely
    adapter.on_cis(0, 1.0);
    adapter.on_crawl(1, 2.0);
    let mut raw2 = LdsScheduler::new(&rates);
    adapter.on_start(rates.len());
    for j in 0..200 {
        assert_eq!(raw2.next(), adapter.select(j as f64), "post-restart step {j}");
    }
}

#[test]
fn builder_output_is_bit_identical_to_hand_construction() {
    let ps = pages(50, 21);
    let horizon = 50.0;
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    let mut trng = Rng::new(22);
    let traces = generate_traces(&ps, horizon, CisDelay::None, &mut trng);

    // exact
    let mut hand = GreedyScheduler::new(PolicyKind::GreedyNcis, &ps, ValueBackend::Native);
    let mut built = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Exact)
        .backend(ValueBackend::Native)
        .pages(&ps)
        .build()
        .unwrap();
    let a = simulate(&traces, &cfg, &mut hand);
    let b = simulate(&traces, &cfg, built.as_mut());
    assert_bit_identical(&a, &b, "builder exact");

    // lazy
    let mut hand = LazyGreedyScheduler::new(PolicyKind::GreedyNcis, &ps);
    let mut built = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&ps)
        .build()
        .unwrap();
    let a = simulate(&traces, &cfg, &mut hand);
    let b = simulate(&traces, &cfg, built.as_mut());
    assert_bit_identical(&a, &b, "builder lazy");

    // sharded
    let mut hand = ncis_crawl::coordinator::shard::ShardedScheduler::new(
        PolicyKind::GreedyNcis,
        &ps,
        4,
        ValueBackend::Native,
    );
    let mut built = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Sharded { shards: 4 })
        .pages(&ps)
        .build()
        .unwrap();
    let a = simulate(&traces, &cfg, &mut hand);
    let b = simulate(&traces, &cfg, built.as_mut());
    assert_bit_identical(&a, &b, "builder sharded");

    // lds
    let rates: Vec<f64> = (0..ps.len()).map(|i| 1.0 + (i % 3) as f64).collect();
    let mut hand = LdsAdapter::new(&rates);
    let mut built =
        CrawlerBuilder::new().strategy(Strategy::Lds).lds_rates(&rates).build().unwrap();
    let a = simulate(&traces, &cfg, &mut hand);
    let b = simulate(&traces, &cfg, built.as_mut());
    assert_bit_identical(&a, &b, "builder lds");
}

#[test]
fn run_cell_serial_and_parallel_agree_for_all_lanes() {
    // the pre-redesign determinism contract, re-asserted on the
    // event-driven schedulers: serial == parallel, bit for bit
    let spec = ExperimentSpec {
        horizon: 30.0,
        bandwidth: 5.0,
        ..ExperimentSpec::section6(24, 4)
    }
    .with_partial_cis()
    .with_false_positives();
    for put in [
        PolicyUnderTest::Greedy(PolicyKind::GreedyNcis),
        PolicyUnderTest::Greedy(PolicyKind::GreedyCisPlus),
        PolicyUnderTest::Lazy(PolicyKind::GreedyNcis),
        PolicyUnderTest::Lds,
    ] {
        let serial = run_cell_serial(&spec, put);
        let parallel = run_cell_with_threads(&spec, put, 3);
        assert_eq!(serial.mean.to_bits(), parallel.mean.to_bits(), "{}: mean", put.name());
        assert_eq!(serial.stderr.to_bits(), parallel.stderr.to_bits(), "{}: stderr", put.name());
        for (i, (a, b)) in serial.mean_rates.iter().zip(&parallel.mean_rates).enumerate() {
            assert_eq!(a.to_bits(), b.to_bits(), "{}: rate[{i}]", put.name());
        }
    }
}

#[test]
fn boxed_trait_object_through_pipeline_path() {
    // Box<dyn CrawlScheduler + Send> must ship across threads and be
    // drivable through the Box blanket impl (the shard-worker contract)
    let ps = pages(32, 31);
    let boxed: Box<dyn CrawlScheduler + Send> = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&ps)
        .build()
        .unwrap();
    let handle = std::thread::spawn(move || {
        let mut sched = boxed;
        sched.on_start(ps.len());
        let mut crawls = 0u32;
        for j in 1usize..=100 {
            let t = j as f64 * 0.1;
            if j % 3 == 0 {
                sched.on_cis(j % ps.len(), t);
            }
            if let Some(i) = sched.select(t) {
                sched.on_crawl(i, t);
                crawls += 1;
            }
        }
        (sched.name(), crawls)
    });
    let (name, crawls) = handle.join().unwrap();
    assert_eq!(name, "GREEDY-NCIS-LAZY");
    assert_eq!(crawls, 100, "lazy must crawl every tick");

    // and end-to-end through the real threaded pipeline
    let template =
        CrawlerBuilder::new().policy(PolicyKind::GreedyNcis).strategy(Strategy::Lazy);
    let cfg = PipelineConfig { shards: 3, queue_depth: 8, bandwidth: 15.0, horizon: 20.0 };
    let report = run_pipeline(&pages(30, 33), &template, &[], &cfg).unwrap();
    assert_eq!(report.total_crawls, 300);
}

#[test]
fn pjrt_backend_constructible_for_every_strategy() {
    // without artifacts the engine load fails — the point here is that
    // the TYPE system accepts Pjrt into exact, lazy and sharded alike
    // (runtime parity is covered by tests/pjrt_parity.rs when built)
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    let Ok(engine) = ncis_crawl::runtime::PjrtEngine::load(&dir) else {
        eprintln!("SKIP: artifacts not built; PJRT-backend construction not exercised");
        return;
    };
    let engine = std::sync::Arc::new(engine);
    let ps = pages(16, 51);
    for strategy in [Strategy::Exact, Strategy::Lazy, Strategy::Sharded { shards: 2 }] {
        let backend = ValueBackend::Pjrt { engine: std::sync::Arc::clone(&engine), terms: 8 };
        let mut sched = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(strategy)
            .backend(backend)
            .pages(&ps)
            .build()
            .unwrap();
        let mut trng = Rng::new(52);
        let traces = generate_traces(&ps, 10.0, CisDelay::None, &mut trng);
        let cfg = SimConfig::new(3.0, 10.0).unwrap();
        let res = simulate(&traces, &cfg, sched.as_mut());
        assert!((0.0..=1.0).contains(&res.accuracy), "{strategy:?}");
    }
}
