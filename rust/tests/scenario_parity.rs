//! Dynamic-world acceptance suite (the scenario-engine contract):
//!
//! 1. an EMPTY scenario is bit-identical to the static engine for
//!    every Strategy × policy combination (the scenario engine is the
//!    same k-way merge, just with a fourth input stream);
//! 2. a churn scenario replayed from the same seed is bit-identical
//!    (scenarios are deterministic, seedable workloads);
//! 3. retiring a page mid-run never yields a post-retirement crawl of
//!    it, and a recycled slot never inherits stale belief/tracker
//!    state (generation-counter audit);
//! 4. a scheduler REUSED across repetitions of a dynamic world is
//!    bit-identical to a fresh one (on_start fully resets the timing
//!    wheel, tracker slots and scratch — the dynamic-state reset
//!    satellite).

use ncis_crawl::coordinator::builder::{CrawlerBuilder, Strategy};
use ncis_crawl::params::PageParams;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::scenario::generators::{
    add_correlated_outages, add_steady_churn, BornPageSpec,
};
use ncis_crawl::scenario::{
    simulate_scenario, simulate_scenario_with, Scenario, ScenarioWorkspace, WorldEvent,
};
use ncis_crawl::sched::{CrawlScheduler, PageTracker};
use ncis_crawl::sim::{generate_traces, simulate, CisDelay, SimConfig, SimResult};

fn pages(m: usize, seed: u64) -> Vec<PageParams> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| PageParams {
            delta: rng.range(0.05, 1.0),
            mu: rng.range(0.05, 1.0),
            lam: rng.f64(),
            nu: rng.range(0.1, 0.5),
        })
        .collect()
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}: accuracy");
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.fresh_hits, b.fresh_hits, "{ctx}: fresh_hits");
    assert_eq!(a.crawl_counts, b.crawl_counts, "{ctx}: crawl_counts");
    assert_eq!(a.ticks, b.ticks, "{ctx}: ticks");
    assert_eq!(a.timeline.len(), b.timeline.len(), "{ctx}: timeline length");
    for (k, (x, y)) in a.timeline.iter().zip(&b.timeline).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: timeline[{k}].t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: timeline[{k}].acc");
    }
}

/// Decorator recording every `(t, pick)` — lets the suite compare
/// pick-for-pick behavior and check liveness windows. Forwards every
/// lifecycle hook (including the dynamic ones) to the inner scheduler.
struct Recorder<S> {
    inner: S,
    picks: Vec<(f64, usize)>,
}

impl<S> Recorder<S> {
    fn new(inner: S) -> Self {
        Self { inner, picks: Vec::new() }
    }
}

impl<S: CrawlScheduler> CrawlScheduler for Recorder<S> {
    fn on_start(&mut self, m: usize) {
        self.picks.clear();
        self.inner.on_start(m);
    }
    fn on_cis(&mut self, page: usize, t: f64) {
        self.inner.on_cis(page, t);
    }
    fn on_crawl(&mut self, page: usize, t: f64) {
        self.inner.on_crawl(page, t);
    }
    fn on_veto(&mut self, page: usize, t: f64) {
        self.inner.on_veto(page, t);
    }
    fn on_page_added(&mut self, page: usize, params: &PageParams, t: f64) {
        self.inner.on_page_added(page, params, t);
    }
    fn on_page_removed(&mut self, page: usize, t: f64) {
        self.inner.on_page_removed(page, t);
    }
    fn on_params_changed(&mut self, page: usize, params: &PageParams, t: f64) {
        self.inner.on_params_changed(page, params, t);
    }
    fn select(&mut self, t: f64) -> Option<usize> {
        let pick = self.inner.select(t);
        if let Some(i) = pick {
            self.picks.push((t, i));
        }
        pick
    }
    fn name(&self) -> String {
        self.inner.name()
    }
}

/// A churn + outage + drift scenario over `ps`.
fn dynamic_scenario(ps: &[PageParams], seed: u64, horizon: f64) -> Scenario {
    let mut sc = Scenario::new(ps.to_vec(), seed);
    add_steady_churn(&mut sc, 0.01, horizon, &BornPageSpec::default(), seed ^ 0xA);
    add_correlated_outages(&mut sc, 4, 3, horizon / 10.0, horizon, seed ^ 0xB);
    sc
}

// ---- 1. empty scenario == static engine, every strategy × policy ----

#[test]
fn empty_scenario_is_bit_identical_to_static_engine_for_all_combos() {
    let m = 40;
    let horizon = 30.0;
    let ps = pages(m, 1);
    let mut rng = Rng::new(2);
    let traces = generate_traces(&ps, horizon, CisDelay::None, &mut rng);
    let mut cfg = SimConfig::new(4.0, horizon).unwrap();
    cfg.timeline_window = Some(16);
    cfg.cis_discard_window = Some(0.1);
    let empty = Scenario::new(ps.clone(), 99);

    let policies = [
        PolicyKind::Greedy,
        PolicyKind::GreedyCis,
        PolicyKind::GreedyNcis,
        PolicyKind::NcisApprox(2),
        PolicyKind::GreedyCisPlus,
    ];
    let strategies = [
        Strategy::Exact,
        Strategy::Lazy,
        Strategy::LazyWithMargin(0.5),
        Strategy::Sharded { shards: 3 },
    ];
    for policy in policies {
        for strategy in strategies {
            let builder = CrawlerBuilder::new()
                .policy(policy)
                .strategy(strategy)
                .pages(&ps);
            let mut s1 = builder.build().unwrap();
            let mut s2 = builder.build().unwrap();
            let a = simulate(&traces, &cfg, s1.as_mut());
            let b = simulate_scenario(&traces, &cfg, &empty, s2.as_mut());
            assert_bit_identical(&a, &b, &format!("{policy:?} × {strategy:?}"));
        }
    }
    // the LDS lane (policy-independent; rates must cover the pages)
    let builder = CrawlerBuilder::new()
        .strategy(Strategy::Lds)
        .pages(&ps)
        .lds_rates(&vec![1.0; m]);
    let mut s1 = builder.build().unwrap();
    let mut s2 = builder.build().unwrap();
    let a = simulate(&traces, &cfg, s1.as_mut());
    let b = simulate_scenario(&traces, &cfg, &empty, s2.as_mut());
    assert_bit_identical(&a, &b, "LDS");
}

// ---- 2. same-seed replay is bit-identical ----

#[test]
fn churn_scenario_replay_is_bit_identical() {
    let horizon = 60.0;
    let ps = pages(60, 3);
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    for strategy in [Strategy::Exact, Strategy::Lazy, Strategy::Sharded { shards: 3 }] {
        let run = || {
            // everything rebuilt from scratch: scenario, traces,
            // scheduler, workspace — only the seeds are shared
            let sc = dynamic_scenario(&ps, 1234, horizon);
            let mut trng = Rng::new(77);
            let traces = generate_traces(&ps, horizon, CisDelay::None, &mut trng);
            let mut sched = Recorder::new(
                CrawlerBuilder::new()
                    .policy(PolicyKind::GreedyNcis)
                    .strategy(strategy)
                    .pages(&ps)
                    .build()
                    .unwrap(),
            );
            let mut ws = ScenarioWorkspace::new();
            let res = simulate_scenario_with(&mut ws, &traces, &cfg, &sc, &mut sched);
            (res, sched.picks, ws.stats)
        };
        let (r1, p1, s1) = run();
        let (r2, p2, s2) = run();
        assert_bit_identical(&r1, &r2, &format!("{strategy:?} replay"));
        assert_eq!(p1, p2, "{strategy:?}: pick streams diverged between replays");
        assert_eq!(s1, s2, "{strategy:?}: world stats diverged between replays");
        assert!(s1.births > 0, "{strategy:?}: churn scenario produced no births");
        assert_eq!(s1.stale_picks, 0, "{strategy:?}: scheduler picked a retired slot");
        assert_eq!(s1.skipped_events, 0, "{strategy:?}: generator emitted a dead-page event");
    }
}

// ---- 3. retirement + recycling audits ----

#[test]
fn retired_page_is_never_crawled_after_retirement() {
    let horizon = 80.0;
    let ps = pages(30, 5);
    // retire three pages at t=20 with NO rebirth: their slots stay
    // dead for the remaining 60 time units
    let mut sc = Scenario::new(ps.clone(), 50);
    for &victim in &[3usize, 11, 27] {
        sc.push(20.0, WorldEvent::PageRetired { page: victim });
    }
    let cfg = SimConfig::new(4.0, horizon).unwrap();
    for strategy in [Strategy::Exact, Strategy::Lazy, Strategy::Sharded { shards: 3 }] {
        let mut trng = Rng::new(51);
        let traces = generate_traces(&ps, horizon, CisDelay::None, &mut trng);
        let mut sched = Recorder::new(
            CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(strategy)
                .pages(&ps)
                .build()
                .unwrap(),
        );
        let mut ws = ScenarioWorkspace::new();
        simulate_scenario_with(&mut ws, &traces, &cfg, &sc, &mut sched);
        assert_eq!(ws.stats.stale_picks, 0, "{strategy:?}");
        for &(t, pick) in &sched.picks {
            if t > 20.0 {
                assert!(
                    ![3, 11, 27].contains(&pick),
                    "{strategy:?}: retired page {pick} crawled at t={t}"
                );
            }
        }
        // the retired pages were crawlable before t=20 (sanity: the
        // test would pass vacuously if they were never candidates)
        assert!(
            sched.picks.iter().any(|&(t, p)| t <= 20.0 && [3, 11, 27].contains(&p)),
            "{strategy:?}: victims were never crawled pre-retirement"
        );
    }
}

/// Scheduler that loves stale state: it selects the page with the most
/// pending CIS (ties → smallest index). If a recycled slot inherited
/// the previous occupant's CIS count, the newcomer would dominate the
/// argmax forever — the audit below would see it crawled.
struct CisHungry {
    tracker: PageTracker,
    live: Vec<bool>,
    /// (slot, generation) observed at every on_page_added.
    added: Vec<(usize, u32)>,
}

impl CisHungry {
    fn new() -> Self {
        Self { tracker: PageTracker::default(), live: Vec::new(), added: Vec::new() }
    }
}

impl CrawlScheduler for CisHungry {
    fn on_start(&mut self, m: usize) {
        self.tracker.reset(m);
        self.live.clear();
        self.live.resize(m, true);
    }
    fn on_cis(&mut self, page: usize, _t: f64) {
        self.tracker.on_cis(page);
    }
    fn on_crawl(&mut self, page: usize, t: f64) {
        self.tracker.on_crawl(page, t);
    }
    fn on_page_added(&mut self, page: usize, _params: &PageParams, t: f64) {
        // the slot-recycling contract: the tracker scrubs the slot and
        // bumps its generation
        self.tracker.add_page(page, t);
        assert_eq!(self.tracker.n_cis(page), 0, "recycled slot kept a stale CIS count");
        assert_eq!(
            self.tracker.last_crawl(page),
            t,
            "recycled slot kept a stale last-crawl time"
        );
        self.added.push((page, self.tracker.generation(page)));
        if page == self.live.len() {
            self.live.push(true);
        } else {
            self.live[page] = true;
        }
    }
    fn on_page_removed(&mut self, page: usize, _t: f64) {
        self.tracker.remove_page(page);
        self.live[page] = false;
    }
    fn select(&mut self, _t: f64) -> Option<usize> {
        let mut best = None;
        let mut best_n = 0u32;
        for i in 0..self.tracker.len() {
            if !self.live[i] {
                continue;
            }
            let n = self.tracker.n_cis(i);
            if best.is_none() || n > best_n {
                best = Some(i);
                best_n = n;
            }
        }
        best
    }
}

#[test]
fn recycled_slot_never_inherits_stale_tracker_state() {
    // page 2 is a CIS firehose (λ=1, high Δ, high ν); pages 0/1 have
    // no CIS at all. It is retired at t=10 and the slot is reborn at
    // t=20 as a CIS-less page. A stale CIS count would make the
    // CIS-hungry scheduler crawl slot 2 forever after rebirth; a clean
    // slot means it is never crawled again (no CIS can ever arrive).
    let ps = vec![
        PageParams { delta: 0.3, mu: 0.5, lam: 0.0, nu: 0.0 },
        PageParams { delta: 0.3, mu: 0.5, lam: 0.0, nu: 0.0 },
        PageParams { delta: 2.0, mu: 0.5, lam: 1.0, nu: 1.0 },
    ];
    let silent = PageParams { delta: 0.5, mu: 0.5, lam: 0.0, nu: 0.0 };
    let sc = Scenario::new(ps.clone(), 60)
        .at(10.0, WorldEvent::PageRetired { page: 2 })
        .at(20.0, WorldEvent::PageBorn { params: silent });
    let mut trng = Rng::new(61);
    let traces = generate_traces(&ps, 60.0, CisDelay::None, &mut trng);
    let cfg = SimConfig::new(2.0, 60.0).unwrap();
    let mut sched = Recorder::new(CisHungry::new());
    let mut ws = ScenarioWorkspace::new();
    simulate_scenario_with(&mut ws, &traces, &cfg, &sc, &mut sched);
    // the firehose dominated before retirement...
    assert!(
        sched.picks.iter().any(|&(t, p)| t <= 10.0 && p == 2),
        "firehose was never crawled pre-retirement"
    );
    // ...took CIS right up to its retirement...
    assert!(ws.stats.retirements == 1 && ws.stats.births == 1);
    // ...and the reborn slot (recycled index 2) is never crawled: a
    // CIS-less newcomer only wins the hungry argmax via leaked state
    for &(t, p) in &sched.picks {
        if t > 20.0 {
            assert_ne!(p, 2, "recycled slot crawled at t={t}: stale state leaked");
        }
    }
    // generation audit: engine and tracker agree the slot is on its
    // second occupant (retire +1, rebirth +1)
    assert_eq!(ws.generation(2), 2);
    assert_eq!(sched.inner.added, vec![(2, 2)]);
    assert_eq!(ws.stats.stale_picks, 0);
}

// ---- 4. reused scheduler across dynamic repetitions == fresh ----

#[test]
fn two_rep_dynamic_reuse_is_bit_identical_to_fresh() {
    let horizon = 50.0;
    let ps = pages(50, 7);
    let sc = dynamic_scenario(&ps, 4321, horizon);
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    for strategy in [Strategy::Exact, Strategy::Lazy, Strategy::Sharded { shards: 3 }] {
        let builder = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(strategy)
            .pages(&ps);
        let mut t1 = Rng::new(70);
        let traces1 = generate_traces(&ps, horizon, CisDelay::None, &mut t1);
        let mut t2 = Rng::new(71);
        let traces2 = generate_traces(&ps, horizon, CisDelay::None, &mut t2);
        // rep 1 + rep 2 on one reused scheduler (and reused workspace)
        let mut reused = Recorder::new(builder.build().unwrap());
        let mut ws = ScenarioWorkspace::new();
        let _ = simulate_scenario_with(&mut ws, &traces1, &cfg, &sc, &mut reused);
        let a = simulate_scenario_with(&mut ws, &traces2, &cfg, &sc, &mut reused);
        // rep 2 alone on a fresh scheduler + fresh workspace
        let mut fresh = Recorder::new(builder.build().unwrap());
        let mut ws2 = ScenarioWorkspace::new();
        let b = simulate_scenario_with(&mut ws2, &traces2, &cfg, &sc, &mut fresh);
        assert_bit_identical(&a, &b, &format!("{strategy:?} reuse"));
        assert_eq!(
            reused.picks, fresh.picks,
            "{strategy:?}: reused scheduler diverged pick-for-pick from fresh"
        );
        assert_eq!(ws.stats, ws2.stats, "{strategy:?}: stats diverged");
    }
}
