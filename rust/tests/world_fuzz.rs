//! Scenario-DSL integration suite: corpus regression replay, the
//! figure-twin bit-identity pins, the parse → render → parse property,
//! and a slice of the fuzz campaign CI runs at full width.

use ncis_crawl::coordinator::builder::Strategy;
use ncis_crawl::fault::{FaultConfig, RetryPolicy};
use ncis_crawl::figures::common::ExperimentSpec;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::scenario::dsl::bit_identical;
use ncis_crawl::scenario::fuzz::{gen_world_dsl, run_fuzz, FuzzConfig};
use ncis_crawl::scenario::generators::{add_steady_churn, BornPageSpec};
use ncis_crawl::scenario::{parse_world, PageSet, WorldAudit, WorldSpec};
use ncis_crawl::serving::RequestTraffic;
use ncis_crawl::sim::{SimResult, TraceMode};
use ncis_crawl::{Scenario, WorldEvent};

fn corpus_dir() -> std::path::PathBuf {
    std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/corpus")
}

fn corpus_files() -> Vec<std::path::PathBuf> {
    let mut files: Vec<_> = std::fs::read_dir(corpus_dir())
        .expect("corpus dir missing")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().map(|x| x == "world").unwrap_or(false))
        .collect();
    files.sort();
    assert!(files.len() >= 4, "corpus unexpectedly small: {files:?}");
    files
}

fn sim_eq(a: &SimResult, b: &SimResult) -> bool {
    a.accuracy.to_bits() == b.accuracy.to_bits()
        && a.requests == b.requests
        && a.fresh_hits == b.fresh_hits
        && a.ticks == b.ticks
        && a.crawl_counts == b.crawl_counts
        && a.timeline.len() == b.timeline.len()
        && a
            .timeline
            .iter()
            .zip(&b.timeline)
            .all(|(x, y)| x.0.to_bits() == y.0.to_bits() && x.1.to_bits() == y.1.to_bits())
}

/// Every committed corpus world parses, round-trips, compiles, passes
/// the static timeline audit, and — when small enough for the tier-1
/// time budget — replays bit-identically in both trace modes.
#[test]
fn corpus_replays_cleanly() {
    for path in corpus_files() {
        let name = path.file_name().unwrap().to_string_lossy().into_owned();
        let text = std::fs::read_to_string(&path).unwrap();
        let spec = WorldSpec::parse(&text).unwrap_or_else(|e| panic!("{name}: {e}"));
        let again = WorldSpec::parse(&spec.render()).unwrap();
        assert_eq!(spec, again, "{name}: round-trip not identity");

        let world = spec.compile().unwrap_or_else(|e| panic!("{name}: {e}"));
        let twin = again.compile().unwrap();
        assert!(
            bit_identical(&world.scenario, &twin.scenario),
            "{name}: canonical form compiled to a different world"
        );

        let mut audit = WorldAudit::new();
        audit.audit_timeline(&world.scenario);
        assert!(audit.ok(), "{name}: timeline audit: {:?}", audit.violations());

        // replay the small worlds through both engines; the fig-scale
        // ones (m = 500..1000) are covered by their bit-identity pins
        // and the release-mode CI fuzz step
        let ticks = world.horizon * world.bandwidth;
        if world.initial_pages().len() > 200 || ticks > 2_000.0 {
            continue;
        }
        for mode in [TraceMode::Materialized, TraceMode::Streamed] {
            let run = || {
                world
                    .crawler()
                    .policy(PolicyKind::GreedyNcis)
                    .strategy(Strategy::Lazy)
                    .trace_mode(mode)
                    .run_scenario(&world.sim_config().unwrap(), 0xD1CE)
                    .unwrap_or_else(|e| panic!("{name}/{mode:?}: {e}"))
            };
            let (r1, r2) = (run(), run());
            assert!(sim_eq(&r1, &r2), "{name}/{mode:?}: replay diverged");
            let mut audit = WorldAudit::new();
            audit.audit_sim(&name, &r1);
            assert!(audit.ok(), "{name}/{mode:?}: {:?}", audit.violations());
        }
    }
}

/// The corpus twin of `fig_scenario` compiles bit-identical to the
/// hand-constructed world inside the figure code.
#[test]
fn fig_scenario_world_is_bit_identical() {
    // the figure's construction, verbatim
    let spec = ExperimentSpec::section6(1000, 1).with_partial_cis().with_false_positives();
    let mut rng = Rng::new(spec.seed);
    let inst = spec.gen_instance(&mut rng).normalized();
    let mut hand = Scenario::new(inst.pages.clone(), 0x5CE7);
    add_steady_churn(&mut hand, 0.005, 400.0, &BornPageSpec::default(), 0x5CE8);
    hand.push(150.0, WorldEvent::CisOutage { pages: PageSet::All, duration: 100.0 });

    // the committed DSL twin
    let text = std::fs::read_to_string(corpus_dir().join("fig_scenario.world")).unwrap();
    let world = parse_world(&text).unwrap();
    assert!(
        bit_identical(&world.scenario, &hand),
        "fig_scenario.world is not bit-identical to the figure's hand-built scenario"
    );
    assert_eq!(world.timeline_window, Some(1000));
    assert_eq!((world.horizon, world.bandwidth), (400.0, 100.0));
}

/// A DSL `faults` block reproduces the fault figure's severest
/// configuration field-for-field, including the generated correlated
/// outage windows. The timeout is the figure's *computed* value
/// (`0.02 × min(severity, 1)`), rendered through `{:?}` so the exact
/// bits round-trip through the text form.
#[test]
fn fig_faults_world_matches_hand_config() {
    let severity = 0.5_f64;
    let timeout = 0.02 * severity.min(1.0);
    // the figure's construction, verbatim
    let mut hand = FaultConfig {
        transient_prob: severity,
        timeout_prob: timeout,
        gone_prob: 0.0,
        hosts: 20,
        outages: Vec::new(),
        seed: 0xFA17,
    };
    hand.add_correlated_outages((severity * 10.0).ceil() as usize, 200.0 / 40.0, 200.0, 0xFA18);

    let text = format!(
        "world horizon=200.0 bandwidth=50.0 scenario_seed=0x0\n\
         pages section6 m=500 seed=0x5eed partial_cis false_positives normalized\n\
         faults transient={severity:?} timeout={timeout:?} gone=0.0 hosts=20 seed=0xfa17\n\
         fault_outages n=5 mean=5.0 seed=0xfa18\n\
         retry backoff\n"
    );
    let world = parse_world(&text).unwrap();
    let got = world.faults.expect("faults block compiled");
    assert_eq!(got.transient_prob.to_bits(), hand.transient_prob.to_bits());
    assert_eq!(got.timeout_prob.to_bits(), hand.timeout_prob.to_bits());
    assert_eq!(got.gone_prob.to_bits(), hand.gone_prob.to_bits());
    assert_eq!((got.hosts, got.seed), (hand.hosts, hand.seed));
    assert_eq!(got.outages, hand.outages, "generated outage windows differ");
    assert_eq!(world.retry, RetryPolicy::default());

    // and the committed corpus twin agrees with the programmatic text
    // (0.02 × 0.5 halves exactly, so `timeout=0.01` is the same bits)
    let corpus = std::fs::read_to_string(corpus_dir().join("fig_faults.world")).unwrap();
    let corpus_world = parse_world(&corpus).unwrap();
    let cfc = corpus_world.faults.expect("corpus faults block");
    assert_eq!(cfc.timeout_prob.to_bits(), hand.timeout_prob.to_bits());
    assert_eq!(cfc.outages, hand.outages);
}

/// A DSL `traffic` block (plus `diurnal` and `request_flash`)
/// reproduces the serving figure's rep-0 traffic exactly.
#[test]
fn fig_serving_world_matches_hand_traffic() {
    // the figure's construction, verbatim (rep = 0)
    let hand = RequestTraffic::new(40.0, 1.1, 0x5EED ^ 0x7AFF)
        .unwrap()
        .with_diurnal(50.0, 0.5)
        .unwrap()
        .with_flash(60.0, 10.0, 250, 120.0)
        .unwrap();

    let text = std::fs::read_to_string(corpus_dir().join("fig_serving.world")).unwrap();
    let world = parse_world(&text).unwrap();
    assert_eq!(world.traffic, Some(hand));
    assert_eq!((world.horizon, world.bandwidth), (200.0, 50.0));
}

/// parse → render → parse is the identity over the fuzzer's whole
/// generation envelope, and the re-parsed canonical form compiles to a
/// bit-identical world.
#[test]
fn dsl_round_trip_property() {
    ncis_crawl::testkit::forall(
        "dsl_round_trip",
        0xD51,
        48,
        |rng| gen_world_dsl(rng.next_u64()),
        |dsl| {
            let spec = WorldSpec::parse(dsl).map_err(|e| format!("parse: {e}\n{dsl}"))?;
            let again = WorldSpec::parse(&spec.render())
                .map_err(|e| format!("re-parse: {e}\n{}", spec.render()))?;
            if spec != again {
                return Err(format!("directives changed across render:\n{dsl}"));
            }
            let a = spec.compile().map_err(|e| format!("compile: {e}\n{dsl}"))?;
            let b = again.compile().map_err(|e| format!("re-compile: {e}"))?;
            if !bit_identical(&a.scenario, &b.scenario) {
                return Err(format!("round-trip world not bit-identical:\n{dsl}"));
            }
            Ok(())
        },
    );
}

/// A slice of the CI fuzz campaign: every lane of every world replays
/// bit-identically and satisfies the invariant audits. CI's
/// `fuzz-smoke` step runs the same campaign at 200 worlds in release
/// mode (`ncis-crawl fuzz --worlds 200`).
#[test]
fn fuzz_campaign_slice_is_clean() {
    let out = run_fuzz(&FuzzConfig { worlds: 30, start_seed: 0x100, budget: None });
    assert_eq!(out.worlds, 30);
    assert!(out.lanes >= 90, "three scenario lanes always run per world");
    assert!(
        out.clean(),
        "fuzz violations:\n{}",
        out.violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n---\n")
    );
}
