//! Request-side serving acceptance suite (the serving-layer contract):
//!
//! 1. a crawler with [`RequestTraffic::off`] is bit-identical to the
//!    plain engines — materialized AND streamed, static AND scenario —
//!    for every Strategy × policy combination (the serving layer is an
//!    extra merge input whose stream is empty, never an extra RNG
//!    draw on the crawl side);
//! 2. loaded traffic leaves the crawl side bit-identical too (the
//!    traffic stream owns its RNG);
//! 3. a same-seed served run replays bit-identically, metrics included;
//! 4. serving sanity: conservation (fresh + stale == served), flash
//!    crowds concentrate serves on their target, and a starved crawler
//!    serves staler copies than a well-provisioned one.

use ncis_crawl::coordinator::builder::{CrawlerBuilder, Strategy};
use ncis_crawl::params::PageParams;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::scenario::generators::{
    add_correlated_outages, add_steady_churn, BornPageSpec,
};
use ncis_crawl::scenario::Scenario;
use ncis_crawl::serving::{RequestTraffic, ServingMetrics, ServingSession};
use ncis_crawl::sim::{
    generate_traces, simulate, simulate_served_with, CisDelay, SimConfig, SimResult,
    SimWorkspace, TraceMode,
};

fn pages(m: usize, seed: u64) -> Vec<PageParams> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| PageParams {
            delta: rng.range(0.05, 1.0),
            mu: rng.range(0.05, 1.0),
            lam: rng.f64(),
            nu: rng.range(0.1, 0.5),
        })
        .collect()
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}: accuracy");
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.fresh_hits, b.fresh_hits, "{ctx}: fresh_hits");
    assert_eq!(a.crawl_counts, b.crawl_counts, "{ctx}: crawl_counts");
    assert_eq!(a.ticks, b.ticks, "{ctx}: ticks");
    assert_eq!(a.timeline.len(), b.timeline.len(), "{ctx}: timeline length");
    for (k, (x, y)) in a.timeline.iter().zip(&b.timeline).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: timeline[{k}].t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: timeline[{k}].acc");
    }
}

fn assert_metrics_identical(a: &ServingMetrics, b: &ServingMetrics, ctx: &str) {
    assert_eq!(a.served, b.served, "{ctx}: served");
    assert_eq!(a.fresh_serves, b.fresh_serves, "{ctx}: fresh_serves");
    assert_eq!(a.stale_serves, b.stale_serves, "{ctx}: stale_serves");
    assert_eq!(a.dead_serves, b.dead_serves, "{ctx}: dead_serves");
    assert_eq!(a.overall.count(), b.overall.count(), "{ctx}: overall count");
    assert_eq!(
        a.overall.mean().to_bits(),
        b.overall.mean().to_bits(),
        "{ctx}: overall mean"
    );
    for q in [0.5, 0.95, 0.99] {
        assert_eq!(
            a.overall.quantile(q).to_bits(),
            b.overall.quantile(q).to_bits(),
            "{ctx}: overall p{}",
            q * 100.0
        );
    }
    for (d, (x, y)) in a.by_quality.iter().zip(&b.by_quality).enumerate() {
        assert_eq!(x.count(), y.count(), "{ctx}: by_quality[{d}] count");
        assert_eq!(
            x.mean().to_bits(),
            y.mean().to_bits(),
            "{ctx}: by_quality[{d}] mean"
        );
    }
    for (d, (x, y)) in a.by_popularity.iter().zip(&b.by_popularity).enumerate() {
        assert_eq!(x.count(), y.count(), "{ctx}: by_popularity[{d}] count");
    }
}

/// A churn + outage scenario over `ps` (same shape as the
/// scenario-parity suite's dynamic world).
fn dynamic_scenario(ps: &[PageParams], seed: u64, horizon: f64) -> Scenario {
    let mut sc = Scenario::new(ps.to_vec(), seed);
    add_steady_churn(&mut sc, 0.01, horizon, &BornPageSpec::default(), seed ^ 0xA);
    add_correlated_outages(&mut sc, 4, 3, horizon / 10.0, horizon, seed ^ 0xB);
    sc
}

// ---- 1. zero traffic == plain engines, every strategy × policy ----

#[test]
fn zero_traffic_is_bit_identical_to_the_static_engine_for_all_combos() {
    let m = 40;
    let horizon = 30.0;
    let trace_seed = 2;
    let ps = pages(m, 1);
    let mut cfg = SimConfig::new(4.0, horizon).unwrap();
    cfg.timeline_window = Some(16);

    let policies = [
        PolicyKind::Greedy,
        PolicyKind::GreedyCis,
        PolicyKind::GreedyNcis,
        PolicyKind::NcisApprox(2),
        PolicyKind::GreedyCisPlus,
    ];
    let strategies = [
        Strategy::Exact,
        Strategy::Lazy,
        Strategy::LazyWithMargin(0.5),
        Strategy::Sharded { shards: 3 },
    ];
    for policy in policies {
        for strategy in strategies {
            for mode in [TraceMode::Materialized, TraceMode::Streamed] {
                let builder = CrawlerBuilder::new()
                    .policy(policy)
                    .strategy(strategy)
                    .pages(&ps)
                    .trace_mode(mode)
                    .with_traffic(RequestTraffic::off());
                let (a, metrics) = builder.run_traffic(&cfg, trace_seed).unwrap();
                // the plain run: same trace seed through the same engine
                let mut sched = builder.build().unwrap();
                let b = match mode {
                    TraceMode::Materialized => {
                        let mut rng = Rng::new(trace_seed);
                        let traces =
                            generate_traces(&ps, horizon, CisDelay::None, &mut rng);
                        simulate(&traces, &cfg, sched.as_mut())
                    }
                    TraceMode::Streamed => {
                        let mut rng = Rng::new(trace_seed);
                        ncis_crawl::sim::simulate_streamed(
                            &ps,
                            &cfg,
                            CisDelay::None,
                            &mut rng,
                            sched.as_mut(),
                        )
                        .unwrap()
                    }
                };
                let ctx = format!("{policy:?} × {strategy:?} × {mode:?}");
                assert_bit_identical(&a, &b, &ctx);
                assert_eq!(metrics.served, 0, "{ctx}: off traffic served a request");
                assert_eq!(metrics.dead_serves, 0, "{ctx}: off traffic hit a dead slot");
            }
        }
    }
    // the LDS lane (policy-independent; rates must cover the pages)
    let builder = CrawlerBuilder::new()
        .strategy(Strategy::Lds)
        .pages(&ps)
        .lds_rates(&vec![1.0; m])
        .with_traffic(RequestTraffic::off());
    let (a, _) = builder.run_traffic(&cfg, trace_seed).unwrap();
    let mut sched = builder.build().unwrap();
    let mut rng = Rng::new(trace_seed);
    let traces = generate_traces(&ps, horizon, CisDelay::None, &mut rng);
    let b = simulate(&traces, &cfg, sched.as_mut());
    assert_bit_identical(&a, &b, "LDS");
}

#[test]
fn zero_traffic_is_bit_identical_to_the_scenario_engine() {
    let horizon = 50.0;
    let ps = pages(50, 7);
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    for strategy in [Strategy::Exact, Strategy::Lazy, Strategy::Sharded { shards: 3 }] {
        for mode in [TraceMode::Materialized, TraceMode::Streamed] {
            let builder = CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(strategy)
                .trace_mode(mode)
                .with_scenario(dynamic_scenario(&ps, 4321, horizon))
                .with_traffic(RequestTraffic::off());
            // run_scenario ignores the traffic; run_traffic must route
            // the very same dynamic world through the served engine
            let (a, metrics) = builder.run_traffic(&cfg, 70).unwrap();
            let b = builder.run_scenario(&cfg, 70).unwrap();
            assert_bit_identical(&a, &b, &format!("{strategy:?} × {mode:?}"));
            assert_eq!(metrics.served, 0, "{strategy:?} × {mode:?}");
        }
    }
}

// ---- 2. loaded traffic never perturbs the crawl side ----

#[test]
fn loaded_traffic_leaves_the_crawl_side_bit_identical() {
    let m = 40;
    let horizon = 30.0;
    let ps = pages(m, 9);
    let cfg = SimConfig::new(4.0, horizon).unwrap();
    let traffic = RequestTraffic::new(25.0, 1.1, 0xBEEF)
        .unwrap()
        .with_diurnal(horizon / 3.0, 0.5)
        .unwrap()
        .with_flash(horizon * 0.4, horizon * 0.1, m - 1, 60.0)
        .unwrap();
    for mode in [TraceMode::Materialized, TraceMode::Streamed] {
        let base = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .pages(&ps)
            .trace_mode(mode);
        let (off, _) = base
            .clone()
            .with_traffic(RequestTraffic::off())
            .run_traffic(&cfg, 11)
            .unwrap();
        let (on, metrics) =
            base.with_traffic(traffic.clone()).run_traffic(&cfg, 11).unwrap();
        assert_bit_identical(&off, &on, &format!("{mode:?} traffic on/off"));
        assert!(metrics.served > 0, "{mode:?}: loaded traffic served nothing");
    }
}

// ---- 3. same-seed served replay is bit-identical, metrics included ----

#[test]
fn same_seed_served_replay_is_bit_identical() {
    let horizon = 40.0;
    let ps = pages(50, 13);
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    for mode in [TraceMode::Materialized, TraceMode::Streamed] {
        let run = || {
            let traffic = RequestTraffic::new(30.0, 1.2, 0xCAFE)
                .unwrap()
                .with_diurnal(10.0, 0.4)
                .unwrap();
            CrawlerBuilder::new()
                .policy(PolicyKind::GreedyNcis)
                .strategy(Strategy::Lazy)
                .trace_mode(mode)
                .with_scenario(dynamic_scenario(&ps, 777, horizon))
                .with_traffic(traffic)
                .run_traffic(&cfg, 21)
                .unwrap()
        };
        let (r1, m1) = run();
        let (r2, m2) = run();
        let ctx = format!("{mode:?} replay");
        assert_bit_identical(&r1, &r2, &ctx);
        assert_metrics_identical(&m1, &m2, &ctx);
        assert!(m1.served > 0, "{ctx}: no requests served");
        assert_eq!(
            m1.fresh_serves + m1.stale_serves,
            m1.served,
            "{ctx}: conservation"
        );
    }
}

// ---- 4. serving sanity ----

#[test]
fn flash_crowd_concentrates_serves_on_its_target() {
    // the flash target is the least-popular page: without the flash its
    // Zipf mass is the smallest of the population, so a serve surplus
    // over its unpopular neighbor can only come from the flash stream
    let m = 30;
    let horizon = 40.0;
    let ps = pages(m, 17);
    let cfg = SimConfig::new(4.0, horizon).unwrap();
    let target = m - 1;
    let neighbor = m - 2;
    let traffic = RequestTraffic::new(20.0, 1.3, 0xF1A5)
        .unwrap()
        .with_flash(5.0, 30.0, target, 200.0)
        .unwrap();
    let mut serving = ServingSession::new(&traffic, &ps, horizon);
    let mut sched = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Lazy)
        .pages(&ps)
        .build()
        .unwrap();
    let mut rng = Rng::new(23);
    let traces = generate_traces(&ps, horizon, CisDelay::None, &mut rng);
    let mut ws = SimWorkspace::new();
    simulate_served_with(&mut ws, &traces, &cfg, sched.as_mut(), &mut serving);
    let cache = serving.cache();
    assert!(
        cache.serves(target) > 10 * cache.serves(neighbor).max(1),
        "flash target got {} serves vs neighbor's {}",
        cache.serves(target),
        cache.serves(neighbor)
    );
    let metrics = serving.metrics();
    assert!(metrics.served > 0);
    assert_eq!(metrics.fresh_serves + metrics.stale_serves, metrics.served);
}

#[test]
fn starved_crawler_serves_staler_copies() {
    let m = 40;
    let horizon = 60.0;
    let ps = pages(m, 29);
    let traffic = RequestTraffic::new(15.0, 1.1, 0xD00D).unwrap();
    let stale_fraction_at = |bandwidth: f64| {
        let cfg = SimConfig::new(bandwidth, horizon).unwrap();
        let (_res, metrics) = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .pages(&ps)
            .with_traffic(traffic.clone())
            .run_traffic(&cfg, 31)
            .unwrap();
        assert!(metrics.served > 0, "R={bandwidth}: nothing served");
        metrics.stale_fraction()
    };
    let starved = stale_fraction_at(0.2);
    let provisioned = stale_fraction_at(20.0);
    assert!(
        starved > provisioned + 0.1,
        "starved crawler ({starved:.3}) must serve staler than provisioned ({provisioned:.3})"
    );
}
