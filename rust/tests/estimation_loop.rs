//! End-to-end tests of the learned-knowledge estimation loop
//! (ISSUE 8): oracle-mode parity, deterministic replay, truth
//! suppression, fault-exact counters, chaos robustness, and cold-start
//! regret convergence.

use ncis_crawl::coordinator::{GreedyScheduler, LearnedScheduler, ValueBackend};
use ncis_crawl::fault::{simulate_faulty, FaultConfig, FaultModel, RetryPolicy};
use ncis_crawl::rngkit::Rng;
use ncis_crawl::scenario::engine::{simulate_scenario_streamed_with, ScenarioWorkspace};
use ncis_crawl::scenario::generators::{
    add_diurnal_drift, add_flash_crowd, add_steady_churn, BornPageSpec,
};
use ncis_crawl::sim::{generate_traces, CisDelay, SimConfig, TraceMode};
use ncis_crawl::{
    CrawlerBuilder, EstimatorConfig, Knowledge, PageParams, PolicyKind, Scenario, Strategy,
};

fn pages(m: usize, seed: u64) -> Vec<PageParams> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| PageParams {
            delta: rng.range(0.05, 1.0),
            mu: rng.range(0.05, 1.0),
            lam: rng.f64(),
            nu: rng.range(0.1, 0.6),
        })
        .collect()
}

/// Project ground-truth pages onto what a learned-mode scheduler may
/// legitimately see at t = 0: observable importance under the
/// uninformative prior, no CIS channel.
fn prior_projection(ps: &[PageParams], cfg: &EstimatorConfig) -> Vec<PageParams> {
    ps.iter().map(|p| PageParams { delta: cfg.prior_delta, mu: p.mu, lam: 0.0, nu: 0.0 }).collect()
}

/// Manual learned stack over a greedy inner scheduler — used where the
/// tests need [`LearnedScheduler`] accessors that the type-erased
/// builder product hides.
fn learned_stack(
    ps: &[PageParams],
    policy: PolicyKind,
    cfg: EstimatorConfig,
) -> LearnedScheduler<GreedyScheduler> {
    let inner = GreedyScheduler::new(policy, &prior_projection(ps, &cfg), ValueBackend::Native);
    LearnedScheduler::new(inner, ps.iter().map(|p| p.mu).collect(), cfg)
}

/// Nearest-earlier-sample resampling of a rolling timeline onto a grid.
fn resample(tl: &[(f64, f64)], grid: &[f64]) -> Vec<f64> {
    let mut out = Vec::with_capacity(grid.len());
    let mut j = 0usize;
    for &t in grid {
        while j + 1 < tl.len() && tl[j + 1].0 <= t {
            j += 1;
        }
        out.push(if tl.is_empty() { f64::NAN } else { tl[j].1 });
    }
    out
}

/// `Knowledge::Oracle` must be bit-identical to the pre-knob builder
/// default across strategy × policy × trace-mode: the knob may not
/// perturb the paper-faithful path in any way.
#[test]
fn oracle_knowledge_is_bit_identical_to_default() {
    let sc = Scenario::new(pages(30, 1), 0xA1);
    let cfg = SimConfig::new(5.0, 30.0).unwrap();
    for strategy in [Strategy::Exact, Strategy::Lazy, Strategy::Sharded { shards: 2 }] {
        for policy in [PolicyKind::Greedy, PolicyKind::GreedyNcis] {
            for mode in [TraceMode::Streamed, TraceMode::Materialized] {
                let base = CrawlerBuilder::new()
                    .policy(policy)
                    .strategy(strategy)
                    .trace_mode(mode)
                    .with_scenario(sc.clone());
                let plain = base.clone().run_scenario(&cfg, 7).unwrap();
                let oracle = base.knowledge(Knowledge::Oracle).run_scenario(&cfg, 7).unwrap();
                let tag = format!("{strategy:?}/{policy:?}/{mode:?}");
                assert_eq!(
                    plain.accuracy.to_bits(),
                    oracle.accuracy.to_bits(),
                    "accuracy diverged under {tag}"
                );
                assert_eq!(plain.crawl_counts, oracle.crawl_counts, "crawls diverged under {tag}");
                assert_eq!(plain.ticks, oracle.ticks, "ticks diverged under {tag}");
            }
        }
    }
}

/// Learned mode replays bit-identically: every estimator stream derives
/// from the master seed via `split64` sub-keys, so same seed + same
/// event stream → the same schedule (satellite: deterministic replay).
#[test]
fn learned_mode_replays_bit_identically() {
    let mut sc = Scenario::new(pages(40, 2), 0xB2);
    add_steady_churn(&mut sc, 0.01, 40.0, &BornPageSpec::default(), 0xB3);
    let cfg = SimConfig::new(8.0, 40.0).unwrap();
    let est = EstimatorConfig { seed: 0xC0FFEE, ..EstimatorConfig::default() };
    let build = |mode: TraceMode| {
        CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Exact)
            .trace_mode(mode)
            .knowledge(Knowledge::Learned(est))
            .with_scenario(sc.clone())
    };
    let a = build(TraceMode::Streamed).run_scenario(&cfg, 9).unwrap();
    let b = build(TraceMode::Streamed).run_scenario(&cfg, 9).unwrap();
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "learned replay diverged");
    assert_eq!(a.crawl_counts, b.crawl_counts);
    assert_eq!(a.ticks, b.ticks);
    // the streamed and materialized engines present the same event
    // sequence, so learned mode inherits their parity
    let c = build(TraceMode::Materialized).run_scenario(&cfg, 9).unwrap();
    assert_eq!(a.accuracy.to_bits(), c.accuracy.to_bits(), "trace-mode parity broke");
    assert_eq!(a.crawl_counts, c.crawl_counts);
    // a reused scheduler must replay identically to a fresh one
    // (`on_start` restores a pristine decorator)
    let mut ws = ScenarioWorkspace::new();
    let mut sched = learned_stack(sc.initial_pages(), PolicyKind::GreedyNcis, est);
    let r1 = simulate_scenario_streamed_with(&mut ws, &cfg, &sc, 9, &mut sched).unwrap();
    let r2 = simulate_scenario_streamed_with(&mut ws, &cfg, &sc, 9, &mut sched).unwrap();
    assert_eq!(r1.accuracy.to_bits(), r2.accuracy.to_bits(), "reused scheduler diverged");
    assert_eq!(r1.crawl_counts, r2.crawl_counts);
}

/// Scenario drift events must not leak ground truth into learned mode:
/// the suppression counter moves, observations accrue from fetches
/// only, and every belief the loop holds stays finite and valid.
#[test]
fn drift_truth_is_suppressed_and_beliefs_stay_valid() {
    let ps = pages(40, 3);
    let mut sc = Scenario::new(ps.clone(), 0xD3);
    add_diurnal_drift(&mut sc, 10.0, 0.5, 4, 0.5, 40.0, 0xD4);
    let cfg = SimConfig::new(8.0, 40.0).unwrap();
    let mut ws = ScenarioWorkspace::new();
    let mut sched = learned_stack(&ps, PolicyKind::GreedyNcis, EstimatorConfig::default());
    let res = simulate_scenario_streamed_with(&mut ws, &cfg, &sc, 11, &mut sched).unwrap();
    assert!((0.0..=1.0).contains(&res.accuracy));
    let stats = *sched.stats();
    assert!(stats.suppressed_truth > 0, "drift emitted no ParamsChanged? {stats:?}");
    assert!(stats.observations > 0, "no fetch observations recorded: {stats:?}");
    assert!(stats.reprojections > 0, "no beliefs were ever re-projected: {stats:?}");
    for page in 0..ps.len() {
        let d = sched.bank().delta_hat(page);
        assert!(d.is_finite() && d > 0.0, "page {page}: delta_hat {d}");
        if let Some(p) = sched.projected(page) {
            assert!(p.validate().is_ok(), "page {page}: invalid projected belief {p:?}");
        }
    }
}

/// Satellite: under injected faults the estimation counters are exact —
/// every successful fetch is one observation, every failed fetch is one
/// skip, and quarantined pages freeze their estimator slots.
#[test]
fn fault_counters_are_exact_and_quarantine_freezes_slots() {
    let ps = pages(40, 4);
    let cfg = SimConfig::new(8.0, 60.0).unwrap();
    let mut trng = Rng::new(0xF5);
    let traces = generate_traces(&ps, 60.0, CisDelay::None, &mut trng);
    let mut model = FaultModel::new(FaultConfig {
        transient_prob: 0.25,
        timeout_prob: 0.05,
        gone_prob: 0.02,
        hosts: 5,
        outages: Vec::new(),
        seed: 0xFA,
    })
    .unwrap();
    let mut sched = learned_stack(&ps, PolicyKind::GreedyNcis, EstimatorConfig::default());
    let res = simulate_faulty(&traces, &cfg, &mut sched, &mut model, RetryPolicy::default());
    let stats = *sched.stats();
    assert_eq!(stats.observations, res.faults.successes, "one observation per successful fetch");
    assert_eq!(stats.skipped_failed, res.faults.failures(), "one skip per failed fetch");
    assert!(res.faults.quarantined > 0, "gone_prob produced no quarantine; weaken the test");
    let frozen = (0..ps.len()).filter(|&p| !sched.bank().is_live(p)).count();
    assert_eq!(frozen as u64, res.faults.quarantined, "quarantine and frozen slots must agree");
    assert_eq!(stats.clamped_nonfinite, 0, "faults must not produce non-finite estimates");
}

/// Chaos sweep: 12 seeds of churn + drift + flash crowd (scenario
/// engine) and transient faults + outages (fault engine), all in
/// learned mode — no panics, finite accuracy, valid beliefs throughout.
#[test]
fn chaos_seeds_stay_finite_in_learned_mode() {
    let horizon = 30.0;
    let cfg = SimConfig::new(6.0, horizon).unwrap();
    for seed in 0..12u64 {
        let ps = pages(30, 100 + seed);
        let mut sc = Scenario::new(ps.clone(), 0xC0 ^ seed);
        add_steady_churn(&mut sc, 0.02, horizon, &BornPageSpec::default(), 0xC1 ^ seed);
        add_diurnal_drift(&mut sc, 8.0, 0.4, 4, 0.3, horizon, 0xC2 ^ seed);
        add_flash_crowd(&mut sc, horizon / 3.0, horizon / 6.0, 0.2, 4.0, 2.0, 0xC3 ^ seed);
        let est = EstimatorConfig { seed: 0xE0 ^ seed, ..EstimatorConfig::default() };
        let mut ws = ScenarioWorkspace::new();
        let mut sched = learned_stack(&ps, PolicyKind::GreedyNcis, est);
        let res =
            simulate_scenario_streamed_with(&mut ws, &cfg, &sc, 0xAB ^ seed, &mut sched).unwrap();
        assert!(
            res.accuracy.is_finite() && (0.0..=1.0).contains(&res.accuracy),
            "seed {seed}: scenario accuracy {}",
            res.accuracy
        );
        for page in 0..ps.len() {
            if let Some(p) = sched.projected(page) {
                assert!(p.validate().is_ok(), "seed {seed} page {page}: {p:?}");
            }
        }

        let mut trng = Rng::new(0xBEEF ^ seed);
        let traces = generate_traces(&ps, horizon, CisDelay::None, &mut trng);
        let mut fault_cfg = FaultConfig {
            transient_prob: 0.2,
            timeout_prob: 0.05,
            gone_prob: 0.01,
            hosts: 4,
            outages: Vec::new(),
            seed: 0xF00 ^ seed,
        };
        fault_cfg.add_correlated_outages(2, horizon / 10.0, horizon, 0xF01 ^ seed);
        let mut model = FaultModel::new(fault_cfg).unwrap();
        let mut fsched = learned_stack(&ps, PolicyKind::GreedyNcis, est);
        let fres = simulate_faulty(&traces, &cfg, &mut fsched, &mut model, RetryPolicy::default());
        assert!(
            fres.sim.accuracy.is_finite() && (0.0..=1.0).contains(&fres.sim.accuracy),
            "seed {seed}: faulty accuracy {}",
            fres.sim.accuracy
        );
        assert_eq!(fsched.stats().observations, fres.faults.successes, "seed {seed}");
        assert_eq!(fsched.stats().skipped_failed, fres.faults.failures(), "seed {seed}");
    }
}

/// Cold-start convergence: in a static world the learned scheduler's
/// regret against the oracle shrinks over the run, and its final
/// rolling freshness lands within 15% of the oracle's.
#[test]
fn cold_start_regret_shrinks_and_converges() {
    let horizon = 120.0;
    let ps = pages(150, 6);
    let sc = Scenario::new(ps, 0xE6);
    let mut cfg = SimConfig::new(25.0, horizon).unwrap();
    cfg.timeline_window = Some(400);
    let grid: Vec<f64> = (1..=horizon as usize).map(|k| k as f64).collect();
    let reps = 3usize;
    let lane = |knowledge: Knowledge| -> Vec<f64> {
        let builder = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Exact)
            .knowledge(knowledge)
            .with_scenario(sc.clone());
        let mut acc = vec![0.0f64; grid.len()];
        for rep in 0..reps {
            let res = builder.run_scenario(&cfg, 0xE7 ^ rep as u64).unwrap();
            for (a, v) in acc.iter_mut().zip(resample(&res.timeline, &grid)) {
                *a += v;
            }
        }
        acc.iter().map(|a| a / reps as f64).collect()
    };
    let oracle = lane(Knowledge::Oracle);
    let learned = lane(Knowledge::Learned(EstimatorConfig::default()));
    let mean = |xs: &[f64]| xs.iter().sum::<f64>() / xs.len() as f64;
    let regret: Vec<f64> = oracle.iter().zip(&learned).map(|(o, l)| o - l).collect();
    // skip the window-fill transient, then compare the first and last
    // thirds of the remaining run
    let body = &regret[10..];
    let third = body.len() / 3;
    let (early, late) = (mean(&body[..third]), mean(&body[body.len() - third..]));
    assert!(
        late <= early + 0.03,
        "cold-start regret must shrink: early {early:.4} -> late {late:.4}"
    );
    let tail = 10;
    let (o_final, l_final) =
        (mean(&oracle[oracle.len() - tail..]), mean(&learned[learned.len() - tail..]));
    assert!(
        l_final >= 0.85 * o_final - 0.03,
        "learned final freshness {l_final:.4} not within 15% of oracle {o_final:.4}"
    );
    assert!(o_final > 0.0, "oracle lane degenerate — test instance broken");
}
