//! Fault-injection suite: the resilience contracts of the fault layer.
//!
//! 1. **Zero-fault bit-parity pins**: the fault engine with the inert
//!    model is bit-identical to the plain engine for every
//!    (strategy × policy) combination, through BOTH the materialized
//!    replay path and the streamed path — the fault layer must be free
//!    when disabled.
//! 2. **Chaos fuzzer**: N random fault timelines (random probabilities,
//!    outages, retry policies, populations), each replayed twice —
//!    bit-identical results — with the engine invariants checked on
//!    every run: bandwidth conservation including wasted ticks, no
//!    crawl of a quarantined page, consistent failure accounting.
//! 3. **Retry bandwidth accounting over bursty outages**: retries
//!    consume real ticks from the same constant-rate budget — the
//!    faulty run executes exactly as many ticks as the fault-free run
//!    on the same schedule, never more.

use ncis_crawl::fault::{
    simulate_faulty_streamed_with, simulate_faulty_with, FaultConfig, FaultModel, HostOutage,
    RetryPolicy,
};
use ncis_crawl::params::PageParams;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::sched::CrawlScheduler;
use ncis_crawl::sim::{
    generate_traces, simulate_streamed_with, simulate_with, CisDelay, SimConfig, SimResult,
    SimWorkspace, StreamedSource,
};
use ncis_crawl::{CrawlerBuilder, Strategy};

fn pages(m: usize, seed: u64) -> Vec<PageParams> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| PageParams {
            delta: rng.range(0.01, 1.0),
            mu: rng.range(0.01, 1.0),
            lam: rng.f64(),
            nu: rng.range(0.0, 0.6),
        })
        .collect()
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}: accuracy");
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.fresh_hits, b.fresh_hits, "{ctx}: fresh_hits");
    assert_eq!(a.crawl_counts, b.crawl_counts, "{ctx}: crawl_counts");
    assert_eq!(a.ticks, b.ticks, "{ctx}: ticks");
    assert_eq!(a.timeline.len(), b.timeline.len(), "{ctx}: timeline length");
    for (k, (x, y)) in a.timeline.iter().zip(&b.timeline).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: timeline[{k}].t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: timeline[{k}].acc");
    }
}

/// Wraps a scheduler and asserts the engine never hands a crawl (or a
/// crawl failure) for a page it already removed — the externally
/// observable form of the quarantine invariant.
struct QuarantineWatch {
    inner: Box<dyn CrawlScheduler + Send>,
    removed: Vec<bool>,
}

impl QuarantineWatch {
    fn new(inner: Box<dyn CrawlScheduler + Send>) -> Self {
        Self { inner, removed: Vec::new() }
    }
}

impl CrawlScheduler for QuarantineWatch {
    fn on_start(&mut self, m: usize) {
        self.removed = vec![false; m];
        self.inner.on_start(m);
    }

    fn on_cis(&mut self, page: usize, t: f64) {
        assert!(!self.removed[page], "CIS for quarantined page {page} at t={t}");
        self.inner.on_cis(page, t);
    }

    fn on_crawl(&mut self, page: usize, t: f64) {
        assert!(!self.removed[page], "crawl of quarantined page {page} at t={t}");
        self.inner.on_crawl(page, t);
    }

    fn on_veto(&mut self, page: usize, t: f64) {
        self.inner.on_veto(page, t);
    }

    fn on_crawl_failed(&mut self, page: usize, t: f64, outcome: ncis_crawl::fault::CrawlOutcome) {
        assert!(!self.removed[page], "failed crawl of quarantined page {page} at t={t}");
        self.inner.on_crawl_failed(page, t, outcome);
    }

    fn on_page_removed(&mut self, page: usize, t: f64) {
        assert!(!self.removed[page], "page {page} removed twice (t={t})");
        self.removed[page] = true;
        self.inner.on_page_removed(page, t);
    }

    fn select(&mut self, t: f64) -> Option<usize> {
        self.inner.select(t)
    }

    fn name(&self) -> String {
        self.inner.name()
    }
}

const COMBOS: &[(Strategy, PolicyKind)] = &[
    (Strategy::Exact, PolicyKind::Greedy),
    (Strategy::Exact, PolicyKind::GreedyNcis),
    (Strategy::Exact, PolicyKind::GreedyCis),
    (Strategy::Lazy, PolicyKind::GreedyNcis),
    (Strategy::Lazy, PolicyKind::NcisApprox(2)),
];

#[test]
fn zero_fault_is_bit_identical_materialized() {
    let pp = pages(120, 0xFA);
    let horizon = 60.0;
    let cfg = SimConfig::new(6.0, horizon).unwrap();
    let mut trng = Rng::new(0xFB);
    let traces = generate_traces(&pp, horizon, CisDelay::None, &mut trng);
    for &(strategy, policy) in COMBOS {
        for retry in [
            RetryPolicy::default(),
            RetryPolicy::Immediate { max_attempts: 2 },
        ] {
            let build = || {
                CrawlerBuilder::new().policy(policy).strategy(strategy).pages(&pp).build().unwrap()
            };
            let mut ws = SimWorkspace::new();
            let mut plain = build();
            let want = simulate_with(&mut ws, &traces, &cfg, plain.as_mut());
            let mut faulty = build();
            let mut model = FaultModel::inert();
            let got =
                simulate_faulty_with(&mut ws, &traces, &cfg, faulty.as_mut(), &mut model, retry);
            let ctx = format!("{strategy:?}/{policy:?}/{retry:?}");
            assert_bit_identical(&want, &got.sim, &ctx);
            assert_eq!(got.faults.failures(), 0, "{ctx}: failures");
            assert_eq!(got.faults.retries, 0, "{ctx}: retries");
            assert_eq!(got.faults.quarantined, 0, "{ctx}: quarantined");
            assert_eq!(got.faults.forfeited_ticks, 0, "{ctx}: forfeited");
        }
    }
}

#[test]
fn zero_fault_is_bit_identical_streamed() {
    let pp = pages(120, 0xFC);
    let horizon = 60.0;
    let cfg = SimConfig::new(6.0, horizon).unwrap();
    for &(strategy, policy) in COMBOS {
        let build = || {
            CrawlerBuilder::new().policy(policy).strategy(strategy).pages(&pp).build().unwrap()
        };
        let src = |seed: u64| {
            let mut trng = Rng::new(seed);
            StreamedSource::new(&pp, horizon, CisDelay::None, &mut trng).unwrap()
        };
        let mut ws = SimWorkspace::new();
        let mut plain = build();
        let want = simulate_streamed_with(&mut ws, src(0xFD), &cfg, plain.as_mut());
        let mut faulty = build();
        let mut model = FaultModel::inert();
        let got = simulate_faulty_streamed_with(
            &mut ws,
            src(0xFD),
            &cfg,
            faulty.as_mut(),
            &mut model,
            RetryPolicy::default(),
        );
        assert_bit_identical(&want, &got.sim, &format!("streamed {strategy:?}/{policy:?}"));
    }
}

/// One random fault timeline of the chaos fuzzer: returns the faulty
/// result so the caller can replay and compare.
fn chaos_run(seed: u64) -> (ncis_crawl::fault::FaultSimResult, String) {
    let mut rng = Rng::new(seed);
    let m = 40 + (rng.next_u64() % 80) as usize;
    let horizon = 30.0 + rng.f64() * 30.0;
    let r = 2.0 + rng.f64() * 6.0;
    let hosts = 1 + (rng.next_u64() % 8) as usize;
    let pp = pages(m, seed ^ 0xA5A5);
    let cfg = SimConfig::new(r, horizon).unwrap();
    let mut fault_cfg = FaultConfig {
        transient_prob: rng.f64() * 0.5,
        timeout_prob: rng.f64() * 0.2,
        gone_prob: rng.f64() * 0.05,
        hosts,
        outages: Vec::new(),
        seed: seed ^ 0x5A5A,
    };
    fault_cfg.add_correlated_outages(
        (rng.next_u64() % 6) as usize,
        1.0 + rng.f64() * 5.0,
        horizon,
        seed ^ 0x0FF,
    );
    let retry = if rng.next_u64() % 2 == 0 {
        RetryPolicy::Immediate { max_attempts: 1 + (rng.next_u64() % 4) as u32 }
    } else {
        RetryPolicy::ExponentialBackoff {
            base: 0.1 + rng.f64(),
            factor: 1.5 + rng.f64(),
            cap: 10.0,
            max_attempts: 1 + (rng.next_u64() % 5) as u32,
        }
    };
    let ctx = format!(
        "seed={seed:#x} m={m} r={r:.2} hosts={hosts} cfg={fault_cfg:?} retry={retry:?}"
    );

    let mut trng = Rng::new(seed ^ 0xBEEF);
    let traces = generate_traces(&pp, horizon, CisDelay::None, &mut trng);
    let inner = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Exact)
        .pages(&pp)
        .build()
        .unwrap();
    let mut sched = QuarantineWatch::new(inner);
    let mut model = FaultModel::new(fault_cfg).unwrap();
    let mut ws = SimWorkspace::new();
    let res = simulate_faulty_with(&mut ws, &traces, &cfg, &mut sched, &mut model, retry);

    // engine invariants on every run
    let f = &res.faults;
    assert_eq!(
        f.successes + f.failures() + f.forfeited_ticks + f.idle_ticks,
        res.sim.ticks,
        "{ctx}: bandwidth conservation"
    );
    assert_eq!(f.attempts, f.successes + f.failures(), "{ctx}: attempt accounting");
    assert!(f.retries <= f.attempts, "{ctx}: retries exceed attempts");
    assert_eq!(
        res.sim.crawl_counts.iter().map(|&c| c as u64).sum::<u64>(),
        f.successes,
        "{ctx}: only successful fetches count as crawls"
    );
    assert_eq!(
        f.retries_per_host.iter().sum::<u64>(),
        f.retries,
        "{ctx}: per-host retry histogram sums to total"
    );
    assert!(f.quarantined as usize <= pp.len(), "{ctx}: quarantined bound");
    (res, ctx)
}

#[test]
fn chaos_fuzzer_is_replay_deterministic() {
    for k in 0..12u64 {
        let seed = 0xC4A05 ^ (k * 0x9E3779B97F4A7C15);
        let (a, ctx) = chaos_run(seed);
        let (b, _) = chaos_run(seed);
        assert_bit_identical(&a.sim, &b.sim, &ctx);
        assert_eq!(a.faults, b.faults, "{ctx}: fault stats replay");
    }
}

/// Bursty outages: the whole fleet goes dark in waves. Retries must be
/// paid from the same constant-rate tick budget — the faulty run can
/// never execute more ticks than the fault-free run on the same
/// schedule, and every tick is accounted for exactly once.
#[test]
fn retry_bandwidth_is_conserved_over_bursty_outages() {
    let pp = pages(100, 0xB00);
    let horizon = 80.0;
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    let mut trng = Rng::new(0xB01);
    let traces = generate_traces(&pp, horizon, CisDelay::None, &mut trng);
    let hosts = 4;
    // three fleet-wide bursts: every host dark over each window
    let mut outages = Vec::new();
    for h in 0..hosts {
        for &(s, e) in &[(10.0, 14.0), (35.0, 42.0), (60.0, 61.5)] {
            outages.push(HostOutage { host: h, start: s, end: e });
        }
    }
    let fault_cfg = FaultConfig {
        transient_prob: 0.1,
        timeout_prob: 0.0,
        gone_prob: 0.0,
        hosts,
        outages,
        seed: 0xB02,
    };

    let build = || {
        CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Exact)
            .pages(&pp)
            .build()
            .unwrap()
    };
    let mut ws = SimWorkspace::new();
    let mut plain = build();
    let want = simulate_with(&mut ws, &traces, &cfg, plain.as_mut());

    for retry in [
        RetryPolicy::Immediate { max_attempts: 6 },
        RetryPolicy::ExponentialBackoff { base: 0.5, factor: 2.0, cap: 8.0, max_attempts: 6 },
    ] {
        let mut sched = build();
        let mut model = FaultModel::new(fault_cfg.clone()).unwrap();
        let res =
            simulate_faulty_with(&mut ws, &traces, &cfg, sched.as_mut(), &mut model, retry);
        let f = &res.faults;
        // same tick budget as the fault-free run: retries reuse ticks,
        // they never mint new ones
        assert_eq!(res.sim.ticks, want.ticks, "{retry:?}: tick budget");
        assert_eq!(
            f.successes + f.failures() + f.forfeited_ticks + f.idle_ticks,
            res.sim.ticks,
            "{retry:?}: conservation"
        );
        // the bursts really bit: timeouts were recorded and retried
        assert!(f.timeouts > 0, "{retry:?}: bursts should time fetches out");
        assert!(f.retries > 0, "{retry:?}: failures should schedule retries");
        // wasted bandwidth shows up as lost successes vs the clean run
        assert!(
            f.successes <= want.ticks,
            "{retry:?}: successes bounded by the schedule"
        );
    }
}

/// Fleet-scale sanity: quarantine (attempt budget exhausted against a
/// permanently dark host) removes pages, and the engine forfeits — not
/// crashes on — later picks of them.
#[test]
fn permanent_outage_quarantines_and_forfeits() {
    let pp = pages(30, 0xD00);
    let horizon = 40.0;
    let cfg = SimConfig::new(3.0, horizon).unwrap();
    let mut trng = Rng::new(0xD01);
    let traces = generate_traces(&pp, horizon, CisDelay::None, &mut trng);
    let hosts = 3;
    // host 0 is dark for the whole horizon: its pages burn their
    // attempt budgets and must end up quarantined
    let fault_cfg = FaultConfig {
        transient_prob: 0.0,
        timeout_prob: 0.0,
        gone_prob: 0.0,
        hosts,
        outages: vec![HostOutage { host: 0, start: 0.0, end: horizon }],
        seed: 0xD02,
    };
    let inner = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Exact)
        .pages(&pp)
        .build()
        .unwrap();
    let mut sched = QuarantineWatch::new(inner);
    let mut model = FaultModel::new(fault_cfg).unwrap();
    let mut ws = SimWorkspace::new();
    let res = simulate_faulty_with(
        &mut ws,
        &traces,
        &cfg,
        &mut sched,
        &mut model,
        RetryPolicy::Immediate { max_attempts: 2 },
    );
    let f = &res.faults;
    assert!(f.quarantined > 0, "dark-host pages should be quarantined");
    assert!(f.timeouts >= 2 * f.quarantined, "each quarantine burnt its attempt budget");
    // pages on the dark host never produced a successful crawl
    for (i, &c) in res.sim.crawl_counts.iter().enumerate() {
        if i % hosts == 0 {
            assert_eq!(c, 0, "page {i} is on the dark host");
        }
    }
    assert_eq!(
        f.successes + f.failures() + f.forfeited_ticks + f.idle_ticks,
        res.sim.ticks,
        "conservation with quarantine forfeits"
    );
}
