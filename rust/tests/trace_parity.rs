//! Flight-recorder acceptance suite (the tracing contract):
//!
//! 1. tracing is strictly observational — a run with a recording
//!    [`TraceHandle`] attached is bit-identical to the untraced run
//!    across strategy × policy × trace-mode, on the static, scenario,
//!    serving and fault engines (tracing adds no RNG draw and no
//!    branch that depends on recorded state);
//! 2. the drained JSONL is deterministic: same seed ⇒ byte-identical
//!    log, shards in index order;
//! 3. the ring buffer holds exactly the newest `capacity` events per
//!    shard and accounts every overwrite;
//! 4. an invariant violation dumps the last events before panicking
//!    (debug builds).

use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::{Arc, Mutex};

use ncis_crawl::coordinator::builder::{CrawlerBuilder, Knowledge, Strategy};
use ncis_crawl::fault::{
    simulate_faulty_traced_with, FaultConfig, FaultModel, RetryPolicy,
};
use ncis_crawl::params::PageParams;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::scenario::generators::{
    add_correlated_outages, add_steady_churn, BornPageSpec,
};
use ncis_crawl::scenario::Scenario;
use ncis_crawl::serving::{RequestTraffic, ServingMetrics};
use ncis_crawl::sim::{generate_traces, CisDelay, SimConfig, SimResult, SimWorkspace, TraceMode};
use ncis_crawl::trace::{self, FlightRecorder, TraceEvent, TraceHandle};
use ncis_crawl::EstimatorConfig;

fn pages(m: usize, seed: u64) -> Vec<PageParams> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| PageParams {
            delta: rng.range(0.05, 1.0),
            mu: rng.range(0.05, 1.0),
            lam: rng.f64(),
            nu: rng.range(0.1, 0.5),
        })
        .collect()
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}: accuracy");
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.fresh_hits, b.fresh_hits, "{ctx}: fresh_hits");
    assert_eq!(a.crawl_counts, b.crawl_counts, "{ctx}: crawl_counts");
    assert_eq!(a.ticks, b.ticks, "{ctx}: ticks");
    assert_eq!(a.timeline.len(), b.timeline.len(), "{ctx}: timeline length");
    for (k, (x, y)) in a.timeline.iter().zip(&b.timeline).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: timeline[{k}].t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: timeline[{k}].acc");
    }
}

fn assert_metrics_identical(a: &ServingMetrics, b: &ServingMetrics, ctx: &str) {
    assert_eq!(a.served, b.served, "{ctx}: served");
    assert_eq!(a.fresh_serves, b.fresh_serves, "{ctx}: fresh_serves");
    assert_eq!(a.stale_serves, b.stale_serves, "{ctx}: stale_serves");
    assert_eq!(a.dead_serves, b.dead_serves, "{ctx}: dead_serves");
    assert_eq!(a.overall.count(), b.overall.count(), "{ctx}: overall count");
    assert_eq!(
        a.overall.mean().to_bits(),
        b.overall.mean().to_bits(),
        "{ctx}: overall mean"
    );
}

/// The serving suite's dynamic world: churn plus correlated outages.
fn dynamic_scenario(ps: &[PageParams], seed: u64, horizon: f64) -> Scenario {
    let mut sc = Scenario::new(ps.to_vec(), seed);
    add_steady_churn(&mut sc, 0.01, horizon, &BornPageSpec::default(), seed ^ 0xA);
    add_correlated_outages(&mut sc, 4, 3, horizon / 10.0, horizon, seed ^ 0xB);
    sc
}

// ---- 1. tracing on == tracing off, bit for bit ----

#[test]
fn tracing_is_bit_identical_on_the_static_engine_for_all_combos() {
    let m = 40;
    let horizon = 30.0;
    let ps = pages(m, 1);
    let mut cfg = SimConfig::new(4.0, horizon).unwrap();
    cfg.timeline_window = Some(16);
    let policies = [PolicyKind::Greedy, PolicyKind::GreedyCis, PolicyKind::GreedyNcis];
    let strategies = [Strategy::Exact, Strategy::Lazy, Strategy::Sharded { shards: 3 }];
    for policy in policies {
        for strategy in strategies {
            for mode in [TraceMode::Materialized, TraceMode::Streamed] {
                let base = CrawlerBuilder::new()
                    .policy(policy)
                    .strategy(strategy)
                    .pages(&ps)
                    .trace_mode(mode)
                    .with_traffic(RequestTraffic::off());
                let (off, _) = base.clone().run_traffic(&cfg, 2).unwrap();
                let handle = TraceHandle::recorder(1 << 16);
                let (on, _) =
                    base.with_trace(handle.clone()).run_traffic(&cfg, 2).unwrap();
                let ctx = format!("{policy:?} × {strategy:?} × {mode:?}");
                assert_bit_identical(&off, &on, &ctx);
                assert!(!handle.drain_jsonl().is_empty(), "{ctx}: empty trace");
            }
        }
    }
}

#[test]
fn tracing_is_bit_identical_on_scenario_with_loaded_serving() {
    let horizon = 50.0;
    let ps = pages(50, 7);
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    let traffic = RequestTraffic::new(25.0, 1.1, 0xBEEF)
        .unwrap()
        .with_flash(horizon * 0.4, horizon * 0.1, 3, 60.0)
        .unwrap();
    for mode in [TraceMode::Materialized, TraceMode::Streamed] {
        let base = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .trace_mode(mode)
            .with_scenario(dynamic_scenario(&ps, 4321, horizon))
            .with_traffic(traffic.clone());
        let (off, m_off) = base.clone().run_traffic(&cfg, 70).unwrap();
        let handle = TraceHandle::recorder(1 << 17);
        let (on, m_on) = base.with_trace(handle.clone()).run_traffic(&cfg, 70).unwrap();
        let ctx = format!("scenario+serving × {mode:?}");
        assert_bit_identical(&off, &on, &ctx);
        assert_metrics_identical(&m_off, &m_on, &ctx);
        let jsonl = handle.drain_jsonl();
        // the dynamic + serving lane exercises the whole taxonomy
        for ev in ["\"ev\":\"crawl\"", "\"ev\":\"serve\"", "\"ev\":\"world\""] {
            assert!(jsonl.contains(ev), "{ctx}: no {ev} event in trace");
        }
    }
}

#[test]
fn tracing_is_bit_identical_on_the_learned_scheduler() {
    // the learned decorator adds trust-gate and re-projection events;
    // neither may perturb its picks. ~40 observations per page so the
    // bank's trust gates (min_obs = 8 + CI tightness) actually open.
    let ps = pages(30, 11);
    let cfg = SimConfig::new(10.0, 120.0).unwrap();
    let est = EstimatorConfig { seed: 0xC0FFEE, ..EstimatorConfig::default() };
    let base = CrawlerBuilder::new()
        .policy(PolicyKind::GreedyNcis)
        .strategy(Strategy::Exact)
        .pages(&ps)
        .knowledge(Knowledge::Learned(est))
        .with_traffic(RequestTraffic::off());
    let (off, _) = base.clone().run_traffic(&cfg, 5).unwrap();
    let handle = TraceHandle::recorder(1 << 16);
    let (on, _) = base.with_trace(handle.clone()).run_traffic(&cfg, 5).unwrap();
    assert_bit_identical(&off, &on, "learned");
    let jsonl = handle.drain_jsonl();
    assert!(jsonl.contains("\"ev\":\"trust_gate\""), "no trust-gate transition traced");
    assert!(jsonl.contains("\"ev\":\"reproject\""), "no re-projection traced");
}

#[test]
fn tracing_is_bit_identical_on_the_fault_engine() {
    let ps = pages(60, 13);
    let horizon = 80.0;
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    let fault_cfg = FaultConfig {
        transient_prob: 0.15,
        timeout_prob: 0.05,
        gone_prob: 0.02,
        seed: 0xFA,
        ..FaultConfig::none()
    };
    fn run(
        ps: &[PageParams],
        cfg: &SimConfig,
        fault_cfg: &FaultConfig,
        tr: Option<&TraceHandle>,
    ) -> ncis_crawl::fault::FaultSimResult {
        let mut sched = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .pages(ps)
            .build()
            .unwrap();
        let mut rng = Rng::new(17);
        let traces = generate_traces(ps, cfg.horizon, CisDelay::None, &mut rng);
        let mut model = FaultModel::new(fault_cfg.clone()).unwrap();
        let mut ws = SimWorkspace::new();
        simulate_faulty_traced_with(
            &mut ws,
            &traces,
            cfg,
            sched.as_mut(),
            &mut model,
            RetryPolicy::default(),
            tr,
        )
    }
    let off = run(&ps, &cfg, &fault_cfg, None);
    let handle = TraceHandle::recorder(1 << 17);
    let on = run(&ps, &cfg, &fault_cfg, Some(&handle));
    assert_bit_identical(&off.sim, &on.sim, "fault engine");
    assert_eq!(off.faults.attempts, on.faults.attempts, "attempts");
    assert_eq!(off.faults.retries, on.faults.retries, "retries");
    assert_eq!(off.faults.quarantined, on.faults.quarantined, "quarantined");
    assert_eq!(off.faults.forfeited_ticks, on.faults.forfeited_ticks, "forfeits");
    let jsonl = handle.drain_jsonl();
    assert!(jsonl.contains("\"ev\":\"crawl_failed\""), "no failure traced");
    assert!(jsonl.contains("\"ev\":\"retry\""), "no retry traced");
}

// ---- 2. deterministic drains ----

#[test]
fn combined_lanes_share_one_recorder_and_drain_deterministically() {
    // the acceptance shape: a scenario+serving run records into shard 0
    // and a fault run into shard 1 of ONE recorder; the drain is
    // non-empty, shard-ordered, and byte-identical across same-seed runs
    let ps = pages(40, 19);
    let horizon = 40.0;
    let cfg = SimConfig::new(4.0, horizon).unwrap();
    let run_both = || {
        let handle = TraceHandle::recorder(1 << 17);
        let builder = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Exact)
            .with_scenario(dynamic_scenario(&ps, 23, horizon))
            .with_traffic(RequestTraffic::new(20.0, 1.1, 0xCAFE).unwrap())
            .with_trace(handle.shard(0));
        let (scen_res, _) = builder.run_traffic(&cfg, 29).unwrap();
        let h1 = handle.shard(1);
        let mut sched = CrawlerBuilder::new()
            .policy(PolicyKind::GreedyNcis)
            .strategy(Strategy::Lazy)
            .pages(&ps)
            .with_trace(h1.clone())
            .build()
            .unwrap();
        let mut rng = Rng::new(31);
        let traces = generate_traces(&ps, horizon, CisDelay::None, &mut rng);
        let mut model = FaultModel::new(FaultConfig {
            transient_prob: 0.1,
            seed: 0xFB,
            ..FaultConfig::none()
        })
        .unwrap();
        let mut ws = SimWorkspace::new();
        let fault_res = simulate_faulty_traced_with(
            &mut ws,
            &traces,
            &cfg,
            sched.as_mut(),
            &mut model,
            RetryPolicy::default(),
            Some(&h1),
        );
        (scen_res, fault_res, handle.drain_jsonl())
    };
    let (s1, f1, j1) = run_both();
    let (s2, f2, j2) = run_both();
    assert!(!j1.is_empty(), "combined drain is empty");
    assert_eq!(j1, j2, "same-seed drains must be byte-identical");
    assert_bit_identical(&s1, &s2, "combined scenario lane replay");
    assert_bit_identical(&f1.sim, &f2.sim, "combined fault lane replay");
    assert!(j1.contains("\"shard\":0,"), "no shard-0 events");
    assert!(j1.contains("\"shard\":1,"), "no shard-1 events");
    // shard-index drain order: every shard-0 line precedes every shard-1
    let first_s1 = j1.find("\"shard\":1,").unwrap();
    let last_s0 = j1.rfind("\"shard\":0,").unwrap();
    assert!(last_s0 < first_s1, "drain must emit shards in index order");
    // every line is a well-formed single-object JSONL record
    for line in j1.lines() {
        assert!(
            line.starts_with("{\"ev\":\"") && line.ends_with('}'),
            "malformed trace line: {line}"
        );
    }
}

// ---- 3. ring-buffer semantics ----

#[test]
fn ring_buffer_keeps_newest_capacity_events_and_counts_overwrites() {
    let cap = 64;
    let mut rec = FlightRecorder::new(cap);
    let total = 1000u32;
    for i in 0..total {
        // two shards, interleaved pushes with distinguishable payloads
        rec.push((i % 2) as usize, TraceEvent::Cis { t: f64::from(i), page: i });
    }
    assert_eq!(rec.len(), 2 * cap, "each shard holds exactly its capacity");
    assert_eq!(rec.dropped(), u64::from(total) - 2 * cap as u64);
    let snap = rec.snapshot();
    // shard 0 first, then shard 1; within a shard, oldest→newest of the
    // newest `cap` events pushed to it
    let shard0: Vec<u32> = snap
        .iter()
        .filter(|(s, _)| *s == 0)
        .map(|(_, ev)| match ev {
            TraceEvent::Cis { page, .. } => *page,
            other => panic!("unexpected event {other:?}"),
        })
        .collect();
    let expect0: Vec<u32> =
        (0..total).filter(|i| i % 2 == 0).rev().take(cap).rev().collect();
    assert_eq!(shard0, expect0, "shard 0 must hold its newest {cap} events in order");
    let pos1 = snap.iter().position(|(s, _)| *s == 1).unwrap();
    assert!(
        snap[..pos1].iter().all(|(s, _)| *s == 0),
        "snapshot must list shard 0 before shard 1"
    );
    rec.clear();
    assert!(rec.is_empty());
}

// ---- 4. dump on violation ----

/// An `io::Write` the panic can't take with it: the buffer outlives the
/// unwound closure via `Arc`.
#[derive(Clone)]
struct SharedBuf(Arc<Mutex<Vec<u8>>>);

impl std::io::Write for SharedBuf {
    fn write(&mut self, buf: &[u8]) -> std::io::Result<usize> {
        self.0.lock().unwrap().extend_from_slice(buf);
        Ok(buf.len())
    }
    fn flush(&mut self) -> std::io::Result<()> {
        Ok(())
    }
}

#[test]
fn violation_dumps_the_event_window_before_panicking() {
    let handle = TraceHandle::recorder(128);
    for i in 0..10u32 {
        trace::emit(Some(&handle), || TraceEvent::Crawl {
            t: f64::from(i),
            page: i,
            changed: i % 2 == 0,
        });
    }
    let buf = SharedBuf(Arc::new(Mutex::new(Vec::new())));
    let mut w = buf.clone();
    let hit = catch_unwind(AssertUnwindSafe(|| {
        trace::check_or_dump(false, Some(&handle), &mut w, "deliberately broken invariant");
    }));
    if !cfg!(debug_assertions) {
        // release builds compile the check away entirely
        assert!(hit.is_ok());
        return;
    }
    assert!(hit.is_err(), "violated invariant must panic in debug builds");
    let dumped = String::from_utf8(buf.0.lock().unwrap().clone()).unwrap();
    assert!(dumped.contains("flight recorder"), "missing dump header: {dumped}");
    assert!(dumped.contains("\"ev\":\"crawl\""), "dump must carry the event window");
    // a satisfied invariant writes nothing and returns
    let ok = catch_unwind(AssertUnwindSafe(|| {
        let mut w2 = buf.clone();
        trace::check_or_dump(true, Some(&handle), &mut w2, "fine");
    }));
    assert!(ok.is_ok());
}
