//! Acceptance suite for zero-materialization event sourcing:
//!
//! 1. **Replay bit-identity** — the frontier merge engine driving a
//!    `ReplaySource` over pre-built traces is bit-identical to the
//!    merged-sort reference engine for every Strategy × policy
//!    combination (the merge refactor changed the plumbing, not one
//!    event application);
//! 2. **Distributional parity** — the lazy `StreamedSource` realizes
//!    the same stochastic process as `generate_traces`: per-kind event
//!    counts, inter-arrival moments and a two-sample KS bound on the
//!    change inter-arrival distribution all agree across modes, and so
//!    do full-simulation accuracies;
//! 3. **Pending-buffer ordering** — under delayed delivery
//!    (`CisDelay::{Exponential, Poisson}`) every page's event stream
//!    still leaves the source in `(time, kind-rank)` order, inside the
//!    horizon (the min-buffer invariant).

use ncis_crawl::coordinator::builder::{CrawlerBuilder, Strategy};
use ncis_crawl::params::PageParams;
use ncis_crawl::policy::PolicyKind;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::sim::{
    generate_traces, simulate, simulate_reference, simulate_streamed, CisDelay, EventSource,
    SimConfig, SimResult, StreamedSource,
};

fn pages(m: usize, seed: u64) -> Vec<PageParams> {
    let mut rng = Rng::new(seed);
    (0..m)
        .map(|_| PageParams {
            delta: rng.range(0.05, 1.0),
            mu: rng.range(0.05, 1.0),
            lam: rng.f64(),
            nu: rng.range(0.1, 0.5),
        })
        .collect()
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}: accuracy");
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.fresh_hits, b.fresh_hits, "{ctx}: fresh_hits");
    assert_eq!(a.crawl_counts, b.crawl_counts, "{ctx}: crawl_counts");
    assert_eq!(a.ticks, b.ticks, "{ctx}: ticks");
    assert_eq!(a.timeline.len(), b.timeline.len(), "{ctx}: timeline length");
    for (k, (x, y)) in a.timeline.iter().zip(&b.timeline).enumerate() {
        assert_eq!(x.0.to_bits(), y.0.to_bits(), "{ctx}: timeline[{k}].t");
        assert_eq!(x.1.to_bits(), y.1.to_bits(), "{ctx}: timeline[{k}].acc");
    }
}

// ---- 1. replay adapter pins the frontier engine to the reference ----

#[test]
fn replay_engine_is_bit_identical_to_reference_for_all_combos() {
    let m = 40;
    let horizon = 30.0;
    let ps = pages(m, 1);
    let mut rng = Rng::new(2);
    let traces = generate_traces(&ps, horizon, CisDelay::Exponential { mean: 0.3 }, &mut rng);
    let mut cfg = SimConfig::new(4.0, horizon).unwrap();
    cfg.timeline_window = Some(16);
    cfg.cis_discard_window = Some(0.1);

    let policies = [
        PolicyKind::Greedy,
        PolicyKind::GreedyCis,
        PolicyKind::GreedyNcis,
        PolicyKind::NcisApprox(2),
        PolicyKind::GreedyCisPlus,
    ];
    let strategies = [
        Strategy::Exact,
        Strategy::Lazy,
        Strategy::LazyWithMargin(0.5),
        Strategy::Sharded { shards: 3 },
    ];
    for policy in policies {
        for strategy in strategies {
            let builder = CrawlerBuilder::new().policy(policy).strategy(strategy).pages(&ps);
            let mut s1 = builder.build().unwrap();
            let mut s2 = builder.build().unwrap();
            let a = simulate(&traces, &cfg, s1.as_mut());
            let b = simulate_reference(&traces, &cfg, s2.as_mut());
            assert_bit_identical(&a, &b, &format!("{policy:?} × {strategy:?}"));
        }
    }
    // the LDS lane (policy-independent)
    let builder =
        CrawlerBuilder::new().strategy(Strategy::Lds).pages(&ps).lds_rates(&vec![1.0; m]);
    let mut s1 = builder.build().unwrap();
    let mut s2 = builder.build().unwrap();
    let a = simulate(&traces, &cfg, s1.as_mut());
    let b = simulate_reference(&traces, &cfg, s2.as_mut());
    assert_bit_identical(&a, &b, "LDS");
}

// ---- 2. streamed vs materialized distributional parity ----

/// Per-kind totals over the whole population.
fn totals(tr: &ncis_crawl::sim::EventTraces) -> (f64, f64, f64) {
    let (c, s, r) = tr.counts();
    (c as f64, s as f64, r as f64)
}

#[test]
fn streamed_counts_match_materialized_and_expectation() {
    // constant-parameter population so expectations are exact:
    // E[changes] = mΔT, E[cis] = m(λΔ + ν)T, E[requests] = mμT
    let m = 60;
    let horizon = 80.0;
    let ps: Vec<PageParams> =
        (0..m).map(|_| PageParams { delta: 0.5, mu: 0.8, lam: 0.6, nu: 0.2 }).collect();
    let mut r1 = Rng::new(11);
    let mut r2 = Rng::new(12);
    let mat = generate_traces(&ps, horizon, CisDelay::None, &mut r1);
    let st = StreamedSource::new(&ps, horizon, CisDelay::None, &mut r2)
        .unwrap()
        .materialize();
    let (ec, es, er) = (
        m as f64 * 0.5 * horizon,
        m as f64 * (0.6 * 0.5 + 0.2) * horizon,
        m as f64 * 0.8 * horizon,
    );
    for (label, expect, a, b) in [
        ("changes", ec, totals(&mat).0, totals(&st).0),
        ("cis", es, totals(&mat).1, totals(&st).1),
        ("requests", er, totals(&mat).2, totals(&st).2),
    ] {
        let tol = 5.0 * expect.sqrt();
        assert!((a - expect).abs() < tol, "{label}: materialized {a} vs E {expect}");
        assert!((b - expect).abs() < tol, "{label}: streamed {b} vs E {expect}");
        assert!((a - b).abs() < 2.0 * tol, "{label}: modes diverge ({a} vs {b})");
    }
}

/// Two-sample Kolmogorov–Smirnov statistic.
fn ks_statistic(mut a: Vec<f64>, mut b: Vec<f64>) -> f64 {
    a.sort_unstable_by(f64::total_cmp);
    b.sort_unstable_by(f64::total_cmp);
    let (n, m) = (a.len(), b.len());
    let (mut i, mut j) = (0usize, 0usize);
    let mut d = 0.0f64;
    while i < n && j < m {
        if a[i] <= b[j] {
            i += 1;
        } else {
            j += 1;
        }
        let diff = (i as f64 / n as f64 - j as f64 / m as f64).abs();
        if diff > d {
            d = diff;
        }
    }
    d
}

fn inter_arrivals(streams: &[Vec<f64>]) -> Vec<f64> {
    let mut out = Vec::new();
    for s in streams {
        for w in s.windows(2) {
            out.push(w[1] - w[0]);
        }
    }
    out
}

#[test]
fn streamed_interarrivals_match_materialized_ks() {
    // same Δ for every page → pooled change inter-arrivals are one
    // Exp(Δ) sample per mode; the two samples must agree (two-sample
    // KS) and match the analytic mean
    let m = 40;
    let horizon = 50.0;
    let delta = 0.8;
    let ps: Vec<PageParams> =
        (0..m).map(|_| PageParams { delta, mu: 0.1, lam: 0.3, nu: 0.1 }).collect();
    let mut r1 = Rng::new(21);
    let mut r2 = Rng::new(22);
    let mat = generate_traces(&ps, horizon, CisDelay::None, &mut r1);
    let st = StreamedSource::new(&ps, horizon, CisDelay::None, &mut r2)
        .unwrap()
        .materialize();
    let a = inter_arrivals(&mat.pages.iter().map(|p| p.changes.clone()).collect::<Vec<_>>());
    let b = inter_arrivals(&st.pages.iter().map(|p| p.changes.clone()).collect::<Vec<_>>());
    assert!(a.len() > 800 && b.len() > 800, "need real sample sizes: {} {}", a.len(), b.len());
    let mean_a: f64 = a.iter().sum::<f64>() / a.len() as f64;
    let mean_b: f64 = b.iter().sum::<f64>() / b.len() as f64;
    // truncation-biased slightly below 1/Δ = 1.25; both modes share
    // it. ~4.5σ bound on the difference of two n≈1500 sample means —
    // catches systematic rate errors, never same-distribution noise
    assert!((mean_a - mean_b).abs() < 0.2, "means diverge: {mean_a} vs {mean_b}");
    assert!((mean_a - 1.25).abs() < 0.2, "materialized mean far from 1/Δ: {mean_a}");
    assert!((mean_b - 1.25).abs() < 0.2, "streamed mean far from 1/Δ: {mean_b}");
    let n_eff = (a.len().min(b.len())) as f64;
    let d = ks_statistic(a, b);
    // D_crit(α=0.05) ≈ 1.36·sqrt(2/n); allow ~2× for a hard bound
    let bound = 2.0 * 1.36 * (2.0 / n_eff).sqrt();
    assert!(d < bound, "KS statistic {d} above bound {bound}");
}

#[test]
fn streamed_accuracy_matches_materialized_across_reps() {
    // full pipeline: same instance, R reps per mode with per-rep
    // seeds, mean accuracies must agree within statistical tolerance
    let ps = pages(50, 31);
    let cfg = SimConfig::new(5.0, 60.0).unwrap();
    let reps = 8u64;
    let builder =
        CrawlerBuilder::new().policy(PolicyKind::GreedyNcis).strategy(Strategy::Lazy).pages(&ps);
    let mut acc_mat = 0.0;
    let mut acc_st = 0.0;
    for rep in 0..reps {
        let mut sched = builder.build().unwrap();
        let mut trng = Rng::new(100 + rep);
        let traces = generate_traces(&ps, cfg.horizon, CisDelay::None, &mut trng);
        acc_mat += simulate(&traces, &cfg, sched.as_mut()).accuracy;

        let mut sched = builder.build().unwrap();
        let mut trng = Rng::new(100 + rep);
        acc_st += simulate_streamed(&ps, &cfg, CisDelay::None, &mut trng, sched.as_mut())
            .unwrap()
            .accuracy;
    }
    let (ma, ms) = (acc_mat / reps as f64, acc_st / reps as f64);
    assert!((0.0..=1.0).contains(&ma) && (0.0..=1.0).contains(&ms));
    assert!(
        (ma - ms).abs() < 0.08,
        "mode accuracies diverge: materialized {ma:.4} vs streamed {ms:.4}"
    );
}

// ---- 3. pending-buffer ordering under delayed delivery ----

#[test]
fn pending_buffer_keeps_order_under_delay_models() {
    for (seed, delay) in [
        (41u64, CisDelay::Exponential { mean: 0.5 }),
        (42, CisDelay::Exponential { mean: 2.0 }),
        (43, CisDelay::Poisson { mean: 6.0, unit: 0.05 }),
        (44, CisDelay::Poisson { mean: 2.0, unit: 0.5 }),
    ] {
        let horizon = 60.0;
        let ps = pages(25, seed);
        let mut rng = Rng::new(seed ^ 0xABC);
        let mut src = StreamedSource::new(&ps, horizon, delay, &mut rng).unwrap();
        let mut total = 0usize;
        for i in 0..src.len() {
            let mut prev: Option<(f64, u8)> = None;
            let mut ev = src.first(i);
            while let Some((t, k)) = ev {
                assert!(
                    (0.0..horizon).contains(&t),
                    "{delay:?} page {i}: event at {t} outside horizon"
                );
                if let Some((pt, pk)) = prev {
                    assert!(
                        pt < t || (pt == t && pk <= k),
                        "{delay:?} page {i}: out of order ({pt}, {pk}) -> ({t}, {k})"
                    );
                }
                prev = Some((t, k));
                total += 1;
                ev = src.advance(i, k);
            }
        }
        assert!(total > 500, "{delay:?}: suspiciously few events ({total})");
    }
}

#[test]
fn delayed_cis_counts_match_materialized() {
    // the delay model reorders and horizon-truncates deliveries; both
    // paths must keep the same delivered-CIS volume
    let m = 50;
    let horizon = 60.0;
    let ps: Vec<PageParams> =
        (0..m).map(|_| PageParams { delta: 0.7, mu: 0.1, lam: 0.8, nu: 0.3 }).collect();
    let delay = CisDelay::Poisson { mean: 6.0, unit: 0.1 };
    let mut r1 = Rng::new(51);
    let mut r2 = Rng::new(52);
    let mat = generate_traces(&ps, horizon, delay, &mut r1);
    let st = StreamedSource::new(&ps, horizon, delay, &mut r2).unwrap().materialize();
    let a = totals(&mat).1;
    let b = totals(&st).1;
    let expect = m as f64 * (0.8 * 0.7 + 0.3) * horizon; // upper bound (pre-truncation)
    assert!(a > 0.5 * expect && b > 0.5 * expect, "deliveries collapsed: {a} {b}");
    assert!((a - b).abs() < 10.0 * expect.sqrt(), "modes diverge: {a} vs {b}");
}
