//! In-process integration tests of the CLI surface.

use ncis_crawl::cli::Args;
use ncis_crawl::run_cli;

fn run(line: &str) -> ncis_crawl::Result<()> {
    let args = Args::parse(line.split_whitespace().map(String::from))?;
    run_cli(&args)
}

#[test]
fn simulate_small() {
    run("simulate --m 20 --r 5 --horizon 40 --reps 2 --policy GREEDY-NCIS --cis --fp").unwrap();
}

#[test]
fn simulate_all_policies() {
    for p in ["GREEDY", "GREEDY-CIS", "G-NCIS-APPROX-2", "GREEDY-CIS+", "LDS"] {
        run(&format!("simulate --m 15 --r 4 --horizon 30 --reps 1 --policy {p} --cis")).unwrap();
    }
}

#[test]
fn solve_reports() {
    run("solve --m 50 --r 20 --cis --fp").unwrap();
}

#[test]
fn dataset_describe() {
    run("dataset --n 5000").unwrap();
}

#[test]
fn estimate_runs() {
    run("estimate --precision 0.5 --recall 0.6").unwrap();
}

#[test]
fn serve_shards_small() {
    run("serve-shards --m 200 --shards 2 --r 50 --horizon 5").unwrap();
}

#[test]
fn experiment_from_config_file() {
    let dir = std::env::temp_dir().join("ncis_cfg_test");
    std::fs::create_dir_all(&dir).unwrap();
    let path = dir.join("exp.toml");
    std::fs::write(
        &path,
        r#"
title = "itest"
reps = 1
policies = ["GREEDY", "GREEDY-NCIS"]

[instance]
m = 20
bandwidth = 5.0
horizon = 30.0
lambda_beta = [0.25, 0.25]
nu_range = [0.1, 0.6]
"#,
    )
    .unwrap();
    run(&format!("experiment --config {}", path.display())).unwrap();
}

#[test]
fn experiment_requires_config() {
    assert!(run("experiment").is_err());
}

#[test]
fn experiment_missing_file_fails() {
    assert!(run("experiment --config /nonexistent/nope.toml").is_err());
}

#[test]
fn figure_unknown_id_fails() {
    assert!(run("figure 99").is_err());
}

#[test]
fn figure_six_runs_fast() {
    run("figure 6").unwrap();
}

#[test]
fn figure_serving_is_deterministic() {
    // every seed in the serving figure derives from the spec seed, so
    // two same-seed runs must emit byte-identical CSV
    run("figure serving --reps 1").unwrap();
    let path = std::path::Path::new("target/figures/fig_serving_fairness.csv");
    let first = std::fs::read(path).unwrap();
    run("figure serving --reps 1").unwrap();
    let second = std::fs::read(path).unwrap();
    assert_eq!(first, second, "same-seed `figure serving` runs diverged");
    // header + 3 policies × (10 quality-decile rows + 1 overall row)
    let text = String::from_utf8(first).unwrap();
    assert_eq!(text.lines().count(), 1 + 3 * 11);
    let header = text.lines().next().unwrap();
    assert!(
        header.starts_with("policy,quality_decile,served,mean_age,p50,p95,p99"),
        "unexpected header: {header}"
    );
}

#[test]
fn figure_regret_is_deterministic() {
    // oracle + learned lanes both derive every seed (scenario, traces,
    // faults, estimator sub-streams) from the spec seed, so two
    // same-seed runs must emit byte-identical CSV
    run("figure regret --reps 1").unwrap();
    let path = std::path::Path::new("target/figures/fig_regret.csv");
    let first = std::fs::read(path).unwrap();
    run("figure regret --reps 1").unwrap();
    let second = std::fs::read(path).unwrap();
    assert_eq!(first, second, "same-seed `figure regret` runs diverged");
    // header + one row per grid point (t = 1..=200)
    let text = String::from_utf8(first).unwrap();
    assert_eq!(text.lines().count(), 1 + 200);
    let header = text.lines().next().unwrap();
    assert!(
        header.starts_with("t,static_oracle,static_learned,static_regret,drift_oracle"),
        "unexpected header: {header}"
    );
}

#[test]
fn unknown_command_fails() {
    assert!(run("frobnicate").is_err());
}

#[test]
fn unknown_policy_fails() {
    assert!(run("simulate --policy NOPE").is_err());
}
