//! Columnar hot-path parity suite.
//!
//! The batched native value kernel (`policy::value::values_ncis_into` +
//! `BeliefModel::values_into`) and the bound-pruned batched argmax
//! (`GreedyScheduler::select`, native backend) are *exact* rewrites of
//! the scalar paths, not approximations. This suite pins that:
//!
//! 1. the batched kernel is **bit-identical** to scalar `value_ncis`
//!    across every `PolicyKind` and the edge regimes γ = 0, β = 0,
//!    β = ∞ and ι = ∞ (tolerance: none — equality is on the bits);
//! 2. full simulations through the batched argmax are bit-identical to
//!    the in-tree scalar reference scan (`select_scalar_reference`);
//! 3. the lazy scheduler on the timing-wheel calendar keeps its
//!    accuracy parity with the exact scheduler (the §5.2 guarantee),
//!    randomized across seeds and policies. The op-level randomized
//!    heap-vs-wheel equivalence lives with the wheel
//!    (`sched::wheel::tests::randomized_equivalence_with_binary_heap_calendar`).

use ncis_crawl::coordinator::crawler::{GreedyScheduler, ValueBackend};
use ncis_crawl::coordinator::lazy::LazyGreedyScheduler;
use ncis_crawl::params::{PageParams, ParamColumns};
use ncis_crawl::policy::{value, BeliefModel, PolicyKind};
use ncis_crawl::rngkit::Rng;
use ncis_crawl::sched::CrawlScheduler;
use ncis_crawl::sim::{generate_traces, simulate, CisDelay, SimConfig, SimResult};

const ALL_POLICIES: [PolicyKind; 5] = [
    PolicyKind::Greedy,
    PolicyKind::GreedyCis,
    PolicyKind::GreedyNcis,
    PolicyKind::NcisApprox(2),
    PolicyKind::GreedyCisPlus,
];

/// Pages covering the §5.1 special cases plus a random noisy population.
fn edge_and_random_pages(m: usize, seed: u64) -> Vec<PageParams> {
    let mut ps = vec![
        PageParams { delta: 0.8, mu: 0.5, lam: 0.0, nu: 0.0 }, // γ = 0 (no CIS)
        PageParams { delta: 0.4, mu: 0.9, lam: 0.0, nu: 0.2 }, // β = 0 (worthless signals)
        PageParams { delta: 1.0, mu: 0.5, lam: 0.7, nu: 0.0 }, // β = ∞ → ι = ∞ on CIS
        PageParams { delta: 1.0, mu: 0.5, lam: 1.0, nu: 0.2 }, // λ = 1 clamp
        PageParams { delta: 1e-3, mu: 1.0, lam: 0.5, nu: 0.3 }, // slow page, huge μ̃/Δ
    ];
    let mut rng = Rng::new(seed);
    ps.extend((0..m).map(|_| PageParams {
        delta: rng.range(0.01, 1.0),
        mu: rng.range(0.01, 1.0),
        lam: rng.f64(),
        nu: rng.range(0.0, 0.6),
    }));
    ps
}

#[test]
fn batched_kernel_bit_identical_to_scalar_value_ncis() {
    let ps = edge_and_random_pages(60, 1);
    let envs: Vec<_> = ps.iter().map(|p| p.derive().unwrap()).collect();
    let cols = ParamColumns::from_derived(&envs);
    // ι grid includes 0, sub-cancellation, generic, huge and ∞
    let iotas = [0.0, 1e-9, 0.4, 2.5, 50.0, 1e6, f64::INFINITY];
    for terms in [1u32, 2, 8, value::MAX_TERMS] {
        let mut flat_iotas = Vec::new();
        let mut flat_pages = Vec::new();
        for i in 0..envs.len() {
            for &iota in &iotas {
                flat_iotas.push(iota);
                flat_pages.push(i as u32);
            }
        }
        let mut out = vec![0.0; flat_iotas.len()];
        value::values_ncis_into(&mut out, &flat_iotas, &flat_pages, &cols, terms);
        for (k, &got) in out.iter().enumerate() {
            let want = value::value_ncis(flat_iotas[k], &envs[flat_pages[k] as usize], terms);
            assert_eq!(
                want.to_bits(),
                got.to_bits(),
                "terms={terms} page={} iota={}: {want} vs {got}",
                flat_pages[k],
                flat_iotas[k]
            );
        }
    }
}

#[test]
fn belief_model_batched_values_bit_identical_for_every_policy() {
    let ps = edge_and_random_pages(200, 2);
    let mut rng = Rng::new(3);
    for kind in ALL_POLICIES {
        let model = BeliefModel::new(kind, &ps);
        let pages: Vec<u32> = (0..ps.len() as u32).collect();
        // states include n_cis = 0 (ι = τ) and n_cis > 0 (β = ∞ pages
        // saturate to ι = ∞ under NCIS beliefs)
        for pass in 0..4 {
            let tau: Vec<f64> = pages.iter().map(|_| rng.range(0.0, 30.0)).collect();
            let n: Vec<u32> = pages
                .iter()
                .map(|_| if pass == 0 { 0 } else { (rng.f64() * 5.0) as u32 })
                .collect();
            let mut out = vec![0.0; ps.len()];
            model.values_into(&pages, &tau, &n, &mut out);
            for (k, &got) in out.iter().enumerate() {
                let want = model.value(k, tau[k], n[k]);
                assert_eq!(want.to_bits(), got.to_bits(), "{kind:?} page {k} pass {pass}");
            }
        }
    }
}

/// `GreedyScheduler` driven through the in-tree scalar reference scan —
/// the pre-columnar evaluation path, verbatim.
struct ScalarGreedy(GreedyScheduler);

impl CrawlScheduler for ScalarGreedy {
    fn on_start(&mut self, m: usize) {
        self.0.on_start(m);
    }
    fn on_cis(&mut self, page: usize, t: f64) {
        self.0.on_cis(page, t);
    }
    fn on_crawl(&mut self, page: usize, t: f64) {
        self.0.on_crawl(page, t);
    }
    fn on_veto(&mut self, page: usize, t: f64) {
        self.0.on_veto(page, t);
    }
    fn select(&mut self, t: f64) -> Option<usize> {
        self.0.select_scalar_reference(t)
    }
    fn name(&self) -> String {
        self.0.name()
    }
}

fn assert_bit_identical(a: &SimResult, b: &SimResult, ctx: &str) {
    assert_eq!(a.accuracy.to_bits(), b.accuracy.to_bits(), "{ctx}: accuracy");
    assert_eq!(a.requests, b.requests, "{ctx}: requests");
    assert_eq!(a.fresh_hits, b.fresh_hits, "{ctx}: fresh_hits");
    assert_eq!(a.crawl_counts, b.crawl_counts, "{ctx}: crawl_counts");
    assert_eq!(a.ticks, b.ticks, "{ctx}: ticks");
}

#[test]
fn batched_argmax_simulations_bit_identical_to_scalar_reference() {
    for (seed, kind) in ALL_POLICIES.iter().enumerate().map(|(s, k)| (s as u64, *k)) {
        let ps = edge_and_random_pages(80, 30 + seed);
        let horizon = 50.0;
        let mut trng = Rng::new(40 + seed);
        let traces = generate_traces(&ps, horizon, CisDelay::None, &mut trng);
        let mut cfg = SimConfig::new(6.0, horizon).unwrap();
        if seed % 2 == 0 {
            cfg.cis_discard_window = Some(0.1);
        }
        let mut fast = GreedyScheduler::new(kind, &ps, ValueBackend::Native);
        let mut slow = ScalarGreedy(GreedyScheduler::new(kind, &ps, ValueBackend::Native));
        let a = simulate(&traces, &cfg, &mut fast);
        let b = simulate(&traces, &cfg, &mut slow);
        assert_bit_identical(&a, &b, &format!("{kind:?}"));
        assert_eq!(
            fast.lambda_estimate.to_bits(),
            slow.0.lambda_estimate.to_bits(),
            "{kind:?}: lambda estimate"
        );
    }
}

#[test]
fn lazy_on_wheel_calendar_keeps_parity_with_exact() {
    // the §5.2 acceptance property, re-pinned on the timing-wheel
    // calendar across seeds and CIS-consuming policies
    for (seed, kind) in
        [(0u64, PolicyKind::GreedyNcis), (1, PolicyKind::GreedyCis), (2, PolicyKind::GreedyNcis)]
    {
        let ps = edge_and_random_pages(200, 50 + seed);
        let horizon = 150.0;
        let cfg = SimConfig::new(8.0, horizon).unwrap();
        let mut acc_exact = 0.0;
        let mut acc_lazy = 0.0;
        let reps = 3u64;
        for rep in 0..reps {
            let mut rng = Rng::new(60 + 10 * seed + rep);
            let traces = generate_traces(&ps, horizon, CisDelay::None, &mut rng);
            let mut ex = GreedyScheduler::new(kind, &ps, ValueBackend::Native);
            let mut lz = LazyGreedyScheduler::new(kind, &ps);
            acc_exact += simulate(&traces, &cfg, &mut ex).accuracy;
            acc_lazy += simulate(&traces, &cfg, &mut lz).accuracy;
        }
        acc_exact /= reps as f64;
        acc_lazy /= reps as f64;
        assert!(
            (acc_exact - acc_lazy).abs() < 0.03,
            "{kind:?} seed {seed}: exact {acc_exact} vs lazy-on-wheel {acc_lazy}"
        );
    }
}
