//! Cross-module property tests (testkit::forall — the proptest stand-in).

use ncis_crawl::coordinator::crawler::{GreedyScheduler, ValueBackend};
use ncis_crawl::coordinator::shard::{rebalance, ShardPlan};
use ncis_crawl::lds::LdsScheduler;
use ncis_crawl::params::DerivedParams;
use ncis_crawl::policy::{value, PolicyKind};
use ncis_crawl::rngkit::Rng;
use ncis_crawl::sim::{generate_traces, simulate, CisDelay, SimConfig};
use ncis_crawl::solver;
use ncis_crawl::testkit::{arb_instance, arb_page, forall};

#[test]
fn prop_value_monotone_in_effective_time() {
    forall(
        "V monotone in iota",
        11,
        300,
        |rng| (arb_page(rng), rng.range(0.01, 20.0), rng.range(0.01, 5.0)),
        |(p, iota, step)| {
            let d = p.derive().map_err(|e| e.to_string())?;
            let v1 = value::value_ncis(*iota, &d, value::MAX_TERMS);
            let v2 = value::value_ncis(iota + step, &d, value::MAX_TERMS);
            if v2 + 1e-12 < v1 {
                return Err(format!("V({}) = {v2} < V({iota}) = {v1}", iota + step));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_value_bounded_by_mu_over_delta() {
    forall(
        "V ≤ μ/Δ",
        12,
        300,
        |rng| (arb_page(rng), rng.range(0.01, 50.0), rng.below(6) as u32),
        |(p, tau, n_cis)| {
            let d = p.derive().map_err(|e| e.to_string())?;
            for kind in [
                PolicyKind::Greedy,
                PolicyKind::GreedyCis,
                PolicyKind::GreedyNcis,
                PolicyKind::NcisApprox(2),
                PolicyKind::GreedyCisPlus,
            ] {
                let v = kind.crawl_value(p, &d, *tau, *n_cis);
                let ub = p.mu / p.delta + 1e-9;
                if v > ub {
                    return Err(format!("{}: V = {v} > μ/Δ = {ub}", kind.name()));
                }
                if v < 0.0 {
                    return Err(format!("{}: V = {v} < 0", kind.name()));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_frequency_inverse_of_psi() {
    forall(
        "f = 1/ψ",
        13,
        200,
        |rng| (arb_page(rng), rng.range(0.05, 20.0)),
        |(p, iota)| {
            let d = p.derive().map_err(|e| e.to_string())?;
            let (psi, _) = value::psi_w(*iota, &d, value::MAX_TERMS);
            let f = value::frequency(*iota, &d, value::MAX_TERMS);
            if (f * psi - 1.0).abs() > 1e-9 {
                return Err(format!("f·ψ = {}", f * psi));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_solver_spends_budget_and_satisfies_kkt() {
    forall(
        "solver KKT",
        14,
        12,
        |rng| {
            let m = 20 + rng.below(100) as usize;
            let r = rng.range(5.0, 60.0);
            arb_instance(rng, m, r, true).normalized()
        },
        |inst| {
            let envs = inst.derived().map_err(|e| e.to_string())?;
            let sol =
                solver::solve_with_cis(inst, &envs, value::MAX_TERMS).map_err(|e| e.to_string())?;
            let total: f64 = sol.rates.iter().sum();
            if (total - inst.bandwidth).abs() > 0.02 * inst.bandwidth {
                return Err(format!("budget {total} vs R {}", inst.bandwidth));
            }
            for (d, &iota) in envs.iter().zip(&sol.thresholds) {
                if iota.is_finite() {
                    let v = value::value_ncis(iota, d, value::MAX_TERMS);
                    if (v - sol.lambda).abs() > 1e-4 * sol.lambda.max(1e-12) {
                        return Err(format!("V(ι*) = {v} ≠ Λ = {}", sol.lambda));
                    }
                } else if d.mu / d.delta > sol.lambda + 1e-9 {
                    return Err("abandoned page with sup V > Λ".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_lds_discrepancy_bounded() {
    forall(
        "LDS discrepancy ≤ 2",
        15,
        25,
        |rng| {
            let k = 2 + rng.below(8) as usize;
            let rates: Vec<f64> = (0..k).map(|_| rng.range(0.05, 1.0)).collect();
            rates
        },
        |rates| {
            let total: f64 = rates.iter().sum();
            let mut lds = LdsScheduler::new(rates);
            let n = 2000;
            let mut counts = vec![0f64; rates.len()];
            for j in 0..n {
                let i = lds.next().ok_or("no page")?;
                counts[i] += 1.0;
                let _ = j;
            }
            for (i, &c) in counts.iter().enumerate() {
                let want = rates[i] / total * n as f64;
                if (c - want).abs() > 2.0 {
                    return Err(format!("page {i}: count {c} vs ideal {want}"));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_simulator_budget_never_exceeded() {
    // the discrete policy must schedule exactly one crawl per tick and
    // ticks must respect the bandwidth over ANY prefix (the paper's
    // "no spikes over any time interval" property)
    forall(
        "discrete budget per interval",
        16,
        6,
        |rng| {
            let m = 10 + rng.below(40) as usize;
            let r = rng.range(2.0, 10.0);
            let inst = arb_instance(rng, m, r, true).normalized();
            let seed = rng.next_u64();
            (inst, seed)
        },
        |(inst, seed)| {
            let horizon = 40.0;
            let mut rng = Rng::new(*seed);
            let traces = generate_traces(&inst.pages, horizon, CisDelay::None, &mut rng);
            let cfg = SimConfig::new(inst.bandwidth, horizon).unwrap();
            let mut sched =
                GreedyScheduler::new(PolicyKind::GreedyNcis, &inst.pages, ValueBackend::Native);
            let res = simulate(&traces, &cfg, &mut sched);
            let total: u64 = res.crawl_counts.iter().map(|&c| c as u64).sum();
            if total != res.ticks {
                return Err(format!("crawls {total} ≠ ticks {}", res.ticks));
            }
            let max_ticks = (inst.bandwidth * horizon).ceil() as u64;
            if res.ticks > max_ticks {
                return Err(format!("ticks {} exceed budget {max_ticks}", res.ticks));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_shard_plans_conserve_pages() {
    forall(
        "shard conservation",
        17,
        50,
        |rng| {
            let m = 1 + rng.below(500) as usize;
            let shards = 1 + rng.below(16) as usize;
            let loads: Vec<f64> = (0..m).map(|_| rng.range(0.0, 1.0)).collect();
            (loads, shards)
        },
        |(loads, shards)| {
            for plan in [ShardPlan::round_robin(loads.len(), *shards), rebalance(loads, *shards)] {
                let members = plan.shard_members();
                let mut seen = vec![false; loads.len()];
                for mem in &members {
                    for &i in mem {
                        if seen[i] {
                            return Err(format!("page {i} assigned twice"));
                        }
                        seen[i] = true;
                    }
                }
                if !seen.iter().all(|&s| s) {
                    return Err("page lost in sharding".into());
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_estimation_quality_roundtrip() {
    // quality_from_theta must invert the (alpha, beta, gamma) derivation
    // for any valid page with CIS
    forall(
        "estimation quality roundtrip",
        19,
        200,
        |rng| {
            let delta = rng.range(0.05, 2.0);
            let precision = rng.range(0.05, 0.99);
            let recall = rng.range(0.05, 0.99);
            (delta, precision, recall)
        },
        |&(delta, precision, recall)| {
            let p = ncis_crawl::params::PageParams::from_quality(delta, 0.1, precision, recall);
            let d = p.derive().map_err(|e| e.to_string())?;
            let kappa = d.alpha * d.beta;
            let (pe, re) = ncis_crawl::estimation::quality_from_theta(d.alpha, kappa, d.gamma);
            if (pe - precision).abs() > 1e-4 {
                return Err(format!("precision {pe} vs {precision}"));
            }
            if (re - recall).abs() > 1e-3 {
                return Err(format!("recall {re} vs {recall}"));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_dataset_corruption_is_bounded_mixture() {
    // corrupted quality stays in [min((1-p)q, ..), (1-p)q + p]
    forall(
        "corruption bounds",
        20,
        20,
        |rng| (rng.range(0.0, 0.5), rng.next_u64()),
        |&(p, seed)| {
            let recs = ncis_crawl::dataset::generate(&ncis_crawl::dataset::DatasetConfig {
                n_urls: 500,
                seed,
                ..Default::default()
            });
            let mut rng = Rng::new(seed ^ 1);
            let c = ncis_crawl::dataset::corrupt(&recs, p, &mut rng);
            for (a, b) in recs.iter().zip(&c) {
                if !a.has_cis {
                    if b.precision != a.precision {
                        return Err("corruption touched a CIS-less page".into());
                    }
                    continue;
                }
                let lo = (1.0 - p) * a.precision;
                let hi = (1.0 - p) * a.precision + p;
                if b.precision < lo - 1e-12 || b.precision > hi + 1e-12 {
                    return Err(format!("precision {} outside [{lo}, {hi}]", b.precision));
                }
            }
            Ok(())
        },
    );
}

#[test]
fn prop_corruption_preserves_unit_interval_marginals() {
    // q ← (1−p)q + p·ξ with ξ ~ U(0,1): every corrupted quality stays
    // a probability, for any mixing weight p ∈ [0,1] — including the
    // endpoints (identity and full replacement) — and the population
    // mean moves to the exact mixture (1−p)·mean(q) + p/2.
    forall(
        "corruption [0,1] marginals",
        27,
        12,
        |rng| (rng.f64(), rng.next_u64()),
        |&(p, seed)| {
            let recs = ncis_crawl::dataset::generate(&ncis_crawl::dataset::DatasetConfig {
                n_urls: 4000,
                seed,
                ..Default::default()
            });
            let mut rng = Rng::new(seed ^ 2);
            let c = ncis_crawl::dataset::corrupt(&recs, p, &mut rng);
            let (mut n, mut mean_before, mut mean_after) = (0usize, 0.0, 0.0);
            for (a, b) in recs.iter().zip(&c) {
                if !a.has_cis {
                    continue;
                }
                for q in [b.precision, b.recall] {
                    if !(0.0..=1.0).contains(&q) {
                        return Err(format!("corrupted quality {q} left [0,1] (p={p})"));
                    }
                }
                n += 1;
                mean_before += a.precision;
                mean_after += b.precision;
            }
            mean_before /= n as f64;
            mean_after /= n as f64;
            let want = (1.0 - p) * mean_before + p * 0.5;
            // ξ-mean sampling error at n ≈ 600 CIS pages: 4σ ≈ 0.05·p
            if (mean_after - want).abs() > 0.05 * p + 1e-9 {
                return Err(format!(
                    "precision mean {mean_after} vs mixture {want} (p={p}, n={n})"
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn dataset_declared_split_is_exact_at_population_scale() {
    // the frac_declared split must be EXACT (round(n·frac) members, a
    // true subset of has_cis), not merely approximate, at the §6.7
    // population scale n = 1e5
    for frac in [0.05, 0.033, 0.5] {
        let n_urls = 100_000usize;
        let recs = ncis_crawl::dataset::generate(&ncis_crawl::dataset::DatasetConfig {
            n_urls,
            seed: 0xF00D,
            frac_declared: frac,
            ..Default::default()
        });
        let want = (n_urls as f64 * frac).round() as usize;
        let declared = recs.iter().filter(|r| r.declared).count();
        assert_eq!(declared, want, "frac={frac}: split must be exact");
        assert!(
            recs.iter().all(|r| !r.declared || r.has_cis),
            "declared must be a subset of has_cis"
        );
        // declared pages carry the upper-tail quality by construction
        assert!(recs
            .iter()
            .filter(|r| r.declared)
            .all(|r| r.precision >= 0.7 && r.recall >= 0.6));
    }
}

#[test]
fn prop_simulator_deterministic_per_seed() {
    forall(
        "simulation determinism",
        21,
        5,
        |rng| rng.next_u64(),
        |&seed| {
            let mut r1 = Rng::new(seed);
            let mut r2 = Rng::new(seed);
            let inst = arb_instance(&mut r1, 30, 5.0, true).normalized();
            let inst2 = arb_instance(&mut r2, 30, 5.0, true).normalized();
            let mut t1 = Rng::new(seed ^ 2);
            let mut t2 = Rng::new(seed ^ 2);
            let tr1 = generate_traces(&inst.pages, 40.0, CisDelay::None, &mut t1);
            let tr2 = generate_traces(&inst2.pages, 40.0, CisDelay::None, &mut t2);
            let cfg = SimConfig::new(5.0, 40.0).unwrap();
            let mut s1 = GreedyScheduler::new(PolicyKind::GreedyNcis, &inst.pages, ValueBackend::Native);
            let mut s2 = GreedyScheduler::new(PolicyKind::GreedyNcis, &inst2.pages, ValueBackend::Native);
            let a = simulate(&tr1, &cfg, &mut s1);
            let b = simulate(&tr2, &cfg, &mut s2);
            if a.accuracy != b.accuracy || a.crawl_counts != b.crawl_counts {
                return Err("same seed produced different runs".into());
            }
            Ok(())
        },
    );
}

#[test]
fn prop_solver_rates_monotone_in_importance() {
    // at the optimum, raising only a page's importance cannot reduce
    // its allocated rate (no-CIS problem)
    forall(
        "rate monotone in mu",
        22,
        10,
        |rng| {
            let inst = arb_instance(rng, 40, 10.0, false);
            let page = rng.below(40) as usize;
            (inst, page)
        },
        |(inst, page)| {
            let base = inst.normalized();
            let sol1 = solver::solve_no_cis(&base).map_err(|e| e.to_string())?;
            let mut boosted = inst.clone();
            boosted.pages[*page].mu *= 3.0;
            let sol2 = solver::solve_no_cis(&boosted.normalized()).map_err(|e| e.to_string())?;
            if sol2.rates[*page] + 1e-9 < sol1.rates[*page] {
                return Err(format!(
                    "rate fell from {} to {} after importance boost",
                    sol1.rates[*page], sol2.rates[*page]
                ));
            }
            Ok(())
        },
    );
}

#[test]
fn prop_effective_time_consistent_with_freshness() {
    // exp(-alpha * tau_eff) must equal the closed-form freshness (eq. 1)
    forall(
        "τ_EFF ↔ freshness",
        18,
        300,
        |rng| {
            let p = arb_page(rng);
            let tau = rng.range(0.0, 10.0);
            // pages with no CIS process (γ = 0) can never receive a signal
            let gamma = p.lam * p.delta + p.nu;
            let n = if gamma > 0.0 { rng.below(4) as u32 } else { 0 };
            (p, tau, n)
        },
        |(p, tau, n)| {
            let d = DerivedParams::from_raw(p);
            let via_eff = (-d.alpha * d.effective_time(*tau, *n)).exp();
            let via_eq1 = d.freshness(*tau, *n);
            if (via_eff - via_eq1).abs() > 1e-9 {
                return Err(format!("{via_eff} vs {via_eq1}"));
            }
            Ok(())
        },
    );
}
