//! PJRT engine vs rust-native parity — the request-path correctness
//! gate: the AOT Pallas kernel running under the `xla` crate must agree
//! with the native f64 implementation (within f32 tolerance) on random
//! batches, including the degenerate corners and padded sentinels.
//!
//! Skips when `artifacts/` has not been built (`make artifacts`).

use std::path::Path;

use ncis_crawl::params::PageParams;
use ncis_crawl::rngkit::Rng;
use ncis_crawl::runtime::{NativeEngine, PjrtEngine, ValueBatch};

// The xla PJRT client is !Send, so each test loads its own engine
// (compilation of the text HLO artifacts is fast).
fn engine() -> Option<PjrtEngine> {
    let dir = Path::new(env!("CARGO_MANIFEST_DIR")).join("artifacts");
    match PjrtEngine::load(&dir) {
        Ok(e) => Some(e),
        Err(err) => {
            eprintln!("SKIP: cannot load artifacts ({err}); run `make artifacts`");
            None
        }
    }
}

fn random_batch(rng: &mut Rng, n: usize) -> ValueBatch {
    let mut b = ValueBatch::with_capacity(n);
    for k in 0..n {
        let corner = k % 8;
        let p = PageParams {
            delta: rng.range(0.01, 2.0),
            mu: rng.range(0.01, 1.0),
            lam: match corner {
                0 => 0.0,
                1 => 1.0,
                _ => rng.f64(),
            },
            nu: if corner <= 1 { 0.0 } else { rng.range(0.0, 1.0) },
        };
        let d = p.derive().unwrap();
        let iota = 10f64.powf(rng.range(-2.0, 1.5));
        b.push(iota, &d);
    }
    b
}

#[test]
fn crawl_values_match_native() {
    let Some(eng) = engine() else { return };
    let native = NativeEngine;
    let mut rng = Rng::new(1);
    for &(terms, n) in &[(2u32, 512usize), (8, 2048), (8, 3000), (2, 20000)] {
        let batch = random_batch(&mut rng, n);
        let got = eng.crawl_values(terms, &batch).unwrap();
        let want = native.crawl_values(terms, &batch);
        assert_eq!(got.len(), n);
        for i in 0..n {
            // absolute floor 1e-3: values below it are freshly-crawled
            // pages whose f32 small-x rounding is irrelevant to argmax
            let scale = want[i].abs().max(1e-3);
            assert!(
                (got[i] - want[i]).abs() / scale < 2e-3,
                "terms={terms} n={n} i={i}: pjrt {} vs native {}",
                got[i],
                want[i]
            );
        }
    }
}

#[test]
fn argmax_matches_native_top_value() {
    let Some(eng) = engine() else { return };
    let native = NativeEngine;
    let mut rng = Rng::new(2);
    for rep in 0..5 {
        let batch = random_batch(&mut rng, 2048);
        let (_, pj_idx, pj_best) = eng.crawl_values_argmax(8, &batch).unwrap();
        let (nat_values, _, nat_best) = native.crawl_values_argmax(8, &batch);
        // indices may differ on near-ties in f32; the selected *value*
        // must be within f32 noise of the true max
        assert!(
            (pj_best - nat_best).abs() / nat_best.abs().max(1e-4) < 2e-3,
            "rep {rep}: pjrt best {pj_best} vs native {nat_best}"
        );
        let at_pj = nat_values[pj_idx];
        assert!(
            (at_pj - nat_best).abs() / nat_best.abs().max(1e-4) < 5e-3,
            "rep {rep}: pjrt argmax picks value {at_pj}, true max {nat_best}"
        );
    }
}

#[test]
fn padded_batch_sentinels_never_win() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(3);
    let mut batch = random_batch(&mut rng, 100); // will pad to 2048
    batch.pad_to(100); // no-op, keep 100 real pages
    let (values, idx, _) = eng.crawl_values_argmax(8, &batch).unwrap();
    assert_eq!(values.len(), 100);
    assert!(idx < 100);
}

#[test]
fn freshness_matches_native() {
    let Some(eng) = engine() else { return };
    let native = NativeEngine;
    let mut rng = Rng::new(4);
    let n = 1000;
    let tau: Vec<f32> = (0..n).map(|_| rng.range(0.0, 10.0) as f32).collect();
    let ncis: Vec<f32> = (0..n).map(|_| rng.below(5) as f32).collect();
    let alpha: Vec<f32> = (0..n).map(|_| rng.range(0.01, 1.0) as f32).collect();
    let logr: Vec<f32> = (0..n).map(|_| -rng.range(0.0, 3.0) as f32).collect();
    let got = eng.freshness(&tau, &ncis, &alpha, &logr).unwrap();
    let want = native.freshness(&tau, &ncis, &alpha, &logr);
    for i in 0..n {
        assert!(
            (got[i] - want[i]).abs() < 1e-5,
            "i={i}: {} vs {}",
            got[i],
            want[i]
        );
    }
}

#[test]
fn mle_fit_recovers_parameters() {
    let Some(eng) = engine() else { return };
    let mut rng = Rng::new(5);
    let (alpha, beta) = (0.4f64, 1.2f64);
    let n = 4096;
    let mut obs = Vec::with_capacity(n);
    let mut z = Vec::with_capacity(n);
    for _ in 0..n {
        let tau = rng.range(0.5, 4.0);
        let ncis = ncis_crawl::rngkit::poisson(&mut rng, 1.0) as f64;
        let p_change = 1.0 - (-(alpha * tau + alpha * beta * ncis)).exp();
        obs.push((tau, ncis));
        z.push(if rng.bernoulli(p_change) { 1.0 } else { 0.0 });
    }
    let (a_hat, k_hat) = eng.mle_fit(&obs, &z, 50).unwrap();
    assert!((a_hat - alpha).abs() < 0.1, "alpha {a_hat} vs {alpha}");
    assert!((k_hat - alpha * beta).abs() < 0.15, "kappa {k_hat} vs {}", alpha * beta);
}

#[test]
fn scheduler_with_pjrt_backend_matches_native_accuracy() {
    use ncis_crawl::coordinator::crawler::{GreedyScheduler, ValueBackend};
    use ncis_crawl::policy::PolicyKind;
    use ncis_crawl::sim::{generate_traces, simulate, CisDelay, SimConfig};
    use std::sync::Arc;
    let Some(eng) = engine() else { return };
    let eng = Arc::new(eng);
    let mut rng = Rng::new(77);
    let pages: Vec<PageParams> = (0..60)
        .map(|_| PageParams {
            delta: rng.range(0.05, 1.0),
            mu: rng.range(0.05, 1.0),
            lam: rng.f64(),
            nu: rng.range(0.1, 0.6),
        })
        .collect();
    let horizon = 80.0;
    let cfg = SimConfig::new(5.0, horizon).unwrap();
    for kind in [PolicyKind::Greedy, PolicyKind::GreedyCis, PolicyKind::GreedyNcis] {
        let mut acc_native = 0.0;
        let mut acc_pjrt = 0.0;
        for rep in 0..2u64 {
            let mut trng = Rng::new(500 + rep);
            let traces = generate_traces(&pages, horizon, CisDelay::None, &mut trng);
            let mut nat = GreedyScheduler::new(kind, &pages, ValueBackend::Native);
            let mut pj = GreedyScheduler::new(
                kind,
                &pages,
                ValueBackend::Pjrt { engine: Arc::clone(&eng), terms: 8 },
            );
            acc_native += simulate(&traces, &cfg, &mut nat).accuracy;
            acc_pjrt += simulate(&traces, &cfg, &mut pj).accuracy;
        }
        // identical traces; only the value backend differs (f32 vs f64,
        // NCIS projection vs closed forms) — accuracies must be close
        assert!(
            (acc_native - acc_pjrt).abs() / 2.0 < 0.03,
            "{}: native {} vs pjrt {}",
            kind.name(),
            acc_native / 2.0,
            acc_pjrt / 2.0
        );
    }
}

#[test]
fn manifest_exposes_expected_configs() {
    let Some(eng) = engine() else { return };
    let configs = eng.crawl_configs();
    assert!(configs.contains(&(2, 2048)));
    assert!(configs.contains(&(8, 2048)));
    assert!(configs.contains(&(8, 16384)));
}
